//! Reasoning-accuracy evaluation across sparsity policies — a compact
//! version of the paper's Fig 5 sweep (the full sweep is
//! `seerattn repro fig5`).
//!
//!     cargo run --release --example reasoning_eval [-- episodes]

use std::rc::Rc;

use anyhow::Result;
use seerattn::coordinator::EngineConfig;
use seerattn::harness;
use seerattn::runtime::Runtime;
use seerattn::sparse::Policy;
use seerattn::workload::reasoning::TaskConfig;

fn main() -> Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    let dir = harness::require_artifacts()?;
    let rt = Rc::new(Runtime::load(&dir)?);
    let task = TaskConfig::hard();
    println!("task: {}-hop chains, {} facts, {n} episodes\n",
             task.hops, task.n_chains * task.hops);
    println!("{:<26} {:>9} {:>9} {:>9} {:>9}",
             "policy", "accuracy", "answered", "gen_len", "kv-touch");
    let configs: Vec<(String, Policy)> = vec![
        ("dense".into(), Policy::Dense),
        ("oracle b=128".into(), Policy::Oracle { budget_tokens: 128 }),
        ("seer b=64".into(), Policy::GateBudget { budget_tokens: 64 }),
        ("seer b=128".into(), Policy::GateBudget { budget_tokens: 128 }),
        ("seer thresh=0.04".into(), Policy::GateThreshold { threshold: 0.04 }),
        ("seer top-p=0.8".into(), Policy::GateTopP { p: 0.8 }),
        ("quest b=64".into(), Policy::Quest { budget_tokens: 64 }),
        ("quest b=128".into(), Policy::Quest { budget_tokens: 128 }),
    ];
    for (name, policy) in configs {
        let ecfg = EngineConfig { policy, block_size: 16, ..Default::default() };
        let mut eng = harness::build_engine(&rt, &dir, ecfg)?;
        let max_new = harness::max_new_for(&task, eng.max_seq());
        let o = harness::eval_policy(&mut eng, task, n, 7, max_new)?;
        println!("{name:<26} {:>8.1}% {:>8.1}% {:>9.1} {:>9.3}",
                 100.0 * o.accuracy, 100.0 * o.answered_frac, o.mean_gen_len,
                 o.kv_touch_fraction);
    }
    Ok(())
}
