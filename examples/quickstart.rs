//! Quickstart: load the AOT artifacts, generate on one reasoning episode
//! with dense attention and with SeerAttention-R sparse decoding, and
//! compare the outputs.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::rc::Rc;

use anyhow::Result;
use seerattn::coordinator::{EngineConfig, Request};
use seerattn::harness;
use seerattn::runtime::Runtime;
use seerattn::sparse::Policy;
use seerattn::util::rng::Rng;
use seerattn::workload::reasoning::{generate, TaskConfig, Vocab};

fn main() -> Result<()> {
    let dir = harness::require_artifacts()?;
    let rt = Rc::new(Runtime::load(&dir)?);
    let vocab = Vocab::default();
    let mut rng = Rng::new(2024);
    let task = TaskConfig { hops: 2, n_chains: 16 };
    let ep = generate(&vocab, &task, &mut rng);
    println!("episode: {} context tokens, {} hops, answer token {}",
             ep.prompt.len(), task.hops, ep.answer);

    for (name, policy) in [
        ("dense (full attention)", Policy::Dense),
        ("seer  (AttnGate, budget 128)", Policy::GateBudget { budget_tokens: 128 }),
        ("quest (baseline, budget 128)", Policy::Quest { budget_tokens: 128 }),
    ] {
        let ecfg = EngineConfig { policy, block_size: 16, ..Default::default() };
        let mut eng = harness::build_engine(&rt, &dir, ecfg)?;
        eng.submit(Request::new(0, ep.prompt.clone(), 32));
        let c = eng.run_to_completion()?.remove(0);
        let verdict = match ep.score(&vocab, &c.generated) {
            Some(true) => "correct",
            Some(false) => "wrong",
            None => "no answer",
        };
        println!(
            "{name:<30} -> {:>2} tokens, {}, kv-touch {:.2}, e2e {:.2}s",
            c.generated.len(),
            verdict,
            eng.metrics.kv_touch_fraction(),
            c.e2e.as_secs_f64()
        );
        println!("   generated: {:?}", c.generated);
    }
    println!("\n(untrained checkpoints give random generations — run \
              `seerattn train` + `seerattn distill` first for real behaviour)");
    Ok(())
}
