//! End-to-end serving benchmark (the e2e validation driver): replay a
//! Poisson arrival trace of reasoning requests through the continuous-
//! batching engine under dense vs. SeerAttention-R sparse decoding, and
//! report latency / throughput / KV-traffic.
//!
//!     cargo run --release --example serve_benchmark [-- n_requests]

use std::rc::Rc;

use anyhow::Result;
use seerattn::coordinator::scheduler::{Replay, TraceRunner};
use seerattn::coordinator::EngineConfig;
use seerattn::harness;
use seerattn::runtime::Runtime;
use seerattn::sparse::Policy;
use seerattn::util::rng::Rng;
use seerattn::util::stats::Series;
use seerattn::workload::trace::poisson_trace;
use seerattn::workload::{TaskConfig, Vocab};

fn main() -> Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let dir = harness::require_artifacts()?;
    let rt = Rc::new(Runtime::load(&dir)?);
    let vocab = Vocab::default();
    let mixture = [TaskConfig::easy(), TaskConfig::hard()];

    println!("serving {n} requests (Poisson trace, virtual-time replay)\n");
    println!("{:<28} {:>9} {:>9} {:>9} {:>10} {:>9}",
             "policy", "tps", "p50 e2e", "p95 e2e", "p50 ttft", "kv-touch");
    for (name, policy) in [
        ("dense", Policy::Dense),
        ("seer budget=128", Policy::GateBudget { budget_tokens: 128 }),
        ("seer budget=256", Policy::GateBudget { budget_tokens: 256 }),
        ("quest budget=128", Policy::Quest { budget_tokens: 128 }),
    ] {
        let mut rng = Rng::new(17);
        let trace = poisson_trace(&vocab, &mixture, n, 50.0, 48, &mut rng);
        let ecfg = EngineConfig { policy, block_size: 16, ..Default::default() };
        let mut eng = harness::build_engine(&rt, &dir, ecfg)?;
        let runner = TraceRunner { replay: Replay::Virtual, ..Default::default() };
        let t0 = std::time::Instant::now();
        let comps = runner.run(&mut eng, &trace)?;
        let wall = t0.elapsed().as_secs_f64();
        let mut e2e = Series::new();
        let mut ttft = Series::new();
        let mut tokens = 0usize;
        for c in &comps {
            e2e.push(c.e2e.as_secs_f64());
            ttft.push(c.ttft.as_secs_f64());
            tokens += c.generated.len();
        }
        println!(
            "{name:<28} {:>9.1} {:>8.2}s {:>8.2}s {:>9.2}s {:>9.3}",
            tokens as f64 / wall,
            e2e.median(),
            e2e.percentile(95.0),
            ttft.median(),
            eng.metrics.kv_touch_fraction()
        );
    }
    println!("\n(decode on this box is not KV-bandwidth-bound at 512-token \
              contexts; kernel-level speedups are in `seerattn repro fig6`)");
    Ok(())
}
