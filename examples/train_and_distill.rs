//! Train the substrate model and distill the AttnGate, end to end, with
//! a short demonstration budget (the full runs are `seerattn train` /
//! `seerattn distill`). Logs both loss curves.
//!
//!     cargo run --release --example train_and_distill [-- steps]

use anyhow::Result;
use seerattn::harness;
use seerattn::model::ParamStore;
use seerattn::runtime::Runtime;
use seerattn::train::{self, TrainConfig};

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let dir = harness::require_artifacts()?;
    let rt = Runtime::load(&dir)?;

    // Phase 1: pretrain the base model on the synthetic reasoning corpus.
    let mut params = ParamStore::load(&dir.join("model_init.bin"), &rt.manifest.params)?;
    println!("== pretraining ({} params, {steps} steps) ==", params.numel());
    let tc = TrainConfig { steps, log_every: 1.max(steps / 10), ..Default::default() };
    let rep = train::pretrain(&rt, &mut params, &tc, |s, l| {
        println!("  step {s:>4}  lm-loss {l:.4}");
    })?;
    println!("pretrain: {:.1}s, {} tokens, final loss {:.4}\n",
             rep.wall_s, rep.tokens_seen, rep.final_loss());
    assert!(rep.final_loss() < rep.losses[0].1,
            "loss must decrease over the demo run");

    // Phase 2: distill the AttnGate against the (partially) trained model.
    let mut gates = ParamStore::load(&dir.join("gate_init.bin"), &rt.manifest.gate_params)?;
    println!("== distilling AttnGate (block 16, {steps} steps) ==");
    let rep = train::distill(&rt, &params, &mut gates, 16, &tc, |s, l| {
        println!("  step {s:>4}  kl {l:.5}");
    })?;
    println!("distill: {:.1}s, final KL {:.5}", rep.wall_s, rep.final_loss());
    assert!(rep.final_loss() < rep.losses[0].1,
            "KL must decrease over the demo run");
    println!("\nOK — use `seerattn train --steps 400` and `seerattn distill` \
              for the full runs recorded in EXPERIMENTS.md");
    Ok(())
}
