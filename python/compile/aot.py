"""AOT driver: lower every executable to HLO *text* + write the manifest.

Run once at build time (``make artifacts``); Python never runs on the
request path. The interchange format is HLO text, NOT serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids which
xla_extension 0.5.1 (the version the Rust ``xla`` crate binds) rejects;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs under ``artifacts/``:
  manifest.json     — configs, parameter layout, executable signatures
  model_init.bin    — initial base-model parameters (raw LE f32)
  gate_init.bin     — initial AttnGate parameters
  fixtures.json     — golden values for Rust-side gate/kcomp parity tests
  *.hlo.txt         — one per executable
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .config import (DEFAULT_AOT, DEFAULT_KBENCH, DEFAULT_MODEL, AotConfig,
                     KernelBenchConfig, ModelConfig)
from . import gate as gate_mod
from . import model as model_mod
from . import params as params_mod
from . import train as train_mod
from .kernels.block_sparse_decode import block_sparse_decode, dense_decode
from .kernels import ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(name, arr):
    arr = jax.ShapeDtypeStruct(arr.shape, arr.dtype) if not isinstance(
        arr, jax.ShapeDtypeStruct) else arr
    dt = {"float32": "f32", "int32": "i32"}[str(arr.dtype)]
    return {"name": name, "dtype": dt, "shape": list(arr.shape)}


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


class Emitter:
    def __init__(self, out_dir: str, only=None):
        self.out_dir = out_dir
        self.manifest_exes = {}
        self.only = only

    def emit(self, name: str, fn, arg_specs, out_names):
        """Lower fn(*args) and record its signature.

        arg_specs: list of (arg_name, ShapeDtypeStruct) — flat positional.
        """
        args = [s for _, s in arg_specs]
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        entry = {
            "file": f"{name}.hlo.txt",
            "args": [_spec(n, s) for n, s in arg_specs],
            "outs": out_names,
        }
        self.manifest_exes[name] = entry
        if self.only is not None and name not in self.only:
            return
        print(f"[aot] lowering {name} ({len(args)} args)", flush=True)
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)


def build_all(out_dir: str, cfg: ModelConfig, aot: AotConfig,
              kb: KernelBenchConfig, only=None, skip_kbench=False):
    os.makedirs(out_dir, exist_ok=True)
    em = Emitter(out_dir, only=only)
    B = aot.decode_batch
    d, dh, dg = cfg.d_model, cfg.head_dim, cfg.d_gate
    H, Hkv, g = cfg.n_heads, cfg.n_kv_heads, cfg.group_size
    L, V, S = cfg.n_layers, cfg.vocab, cfg.max_seq
    mh = cfg.mlp_hidden

    pspecs = params_mod.param_specs(cfg)
    gspecs = params_mod.gate_specs(cfg)
    p_args = [(f"param:{n}", f32(*s)) for n, s in pspecs]
    g_args = [(f"gate:{n}", f32(*s)) for n, s in gspecs]
    nP, nG = len(pspecs), len(gspecs)

    # --- decode path -------------------------------------------------------
    em.emit(
        "layer_pre",
        lambda x, pos, wq, wk, wv, ln1, wqg: model_mod.layer_pre(
            x, pos, wq, wk, wv, ln1, wqg, cfg),
        [("x", f32(B, d)), ("pos", i32(B)), ("wq", f32(d, H * dh)),
         ("wk", f32(d, Hkv * dh)), ("wv", f32(d, Hkv * dh)),
         ("ln1", f32(d)), ("wq_gate", f32(Hkv, g * dh, dg))],
        ["q_rope", "k_rope", "v", "k_pre", "q_gate"],
    )
    for T in aot.sel_token_variants:
        em.emit(
            f"layer_post_sel_t{T}",
            lambda q, ks, vs, m, r, wo, w1, w2, ln2: (
                model_mod.layer_post_sel(q, ks, vs, m, r, wo, w1, w2, ln2,
                                         cfg),),
            [("q_rope", f32(B, H, dh)), ("k_sel", f32(B, Hkv, T, dh)),
             ("v_sel", f32(B, Hkv, T, dh)), ("sel_mask", f32(B, Hkv, T)),
             ("resid", f32(B, d)), ("wo", f32(H * dh, d)),
             ("w1", f32(d, mh)), ("w2", f32(mh, d)), ("ln2", f32(d))],
            ["x_out"],
        )
    for T in aot.sel_token_variants:
        em.emit(
            f"layer_post_selh_t{T}",
            lambda q, ks, vs, m, r, wo, w1, w2, ln2: (
                model_mod.layer_post_sel_perhead(q, ks, vs, m, r, wo, w1,
                                                 w2, ln2, cfg),),
            [("q_rope", f32(B, H, dh)), ("k_sel", f32(B, H, T, dh)),
             ("v_sel", f32(B, H, T, dh)), ("sel_mask", f32(B, H, T)),
             ("resid", f32(B, d)), ("wo", f32(H * dh, d)),
             ("w1", f32(d, mh)), ("w2", f32(mh, d)), ("ln2", f32(d))],
            ["x_out"],
        )
    em.emit(
        "layer_post_dense",
        lambda q, kc, vc, sl, r, wo, w1, w2, ln2: (
            model_mod.layer_post_dense(q, kc, vc, sl, r, wo, w1, w2, ln2,
                                       cfg),),
        [("q_rope", f32(B, H, dh)), ("k_cache", f32(B, Hkv, S, dh)),
         ("v_cache", f32(B, Hkv, S, dh)), ("seq_len", i32(B)),
         ("resid", f32(B, d)), ("wo", f32(H * dh, d)), ("w1", f32(d, mh)),
         ("w2", f32(mh, d)), ("ln2", f32(d))],
        ["x_out"],
    )
    em.emit(
        "lm_head",
        lambda x, lnf, head: (model_mod.lm_head(x, lnf, head, cfg),),
        [("x", f32(B, d)), ("ln_f", f32(d)), ("head", f32(d, V))],
        ["logits"],
    )
    em.emit(
        "prefill",
        lambda *a: model_mod.prefill(list(a[:nP]), cfg, a[nP], a[nP + 1]),
        p_args + [("ids", i32(B, S)), ("seq_len", i32(B))],
        ["logits", "k_rope", "v", "k_pre"],
    )

    # --- training ----------------------------------------------------------
    TB, TS = aot.train_batch, aot.train_len
    m_args = [(f"m:{n}", f32(*s)) for n, s in pspecs]
    v_args = [(f"v:{n}", f32(*s)) for n, s in pspecs]

    def pretrain_fn(*a):
        ps = list(a[:nP])
        ms = list(a[nP:2 * nP])
        vs = list(a[2 * nP:3 * nP])
        step, lr, ids, loss_w = a[3 * nP:]
        new_p, new_m, new_v, loss = train_mod.pretrain_step(
            ps, ms, vs, step, lr, ids, loss_w, cfg)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)

    em.emit(
        "pretrain_step", pretrain_fn,
        p_args + m_args + v_args +
        [("step", f32()), ("lr", f32()), ("ids", i32(TB, TS)),
         ("loss_w", f32(TB, TS))],
        [f"param:{n}" for n, _ in pspecs] + [f"m:{n}" for n, _ in pspecs] +
        [f"v:{n}" for n, _ in pspecs] + ["loss"],
    )

    gm_args = [(f"gm:{n}", f32(*s)) for n, s in gspecs]
    gv_args = [(f"gv:{n}", f32(*s)) for n, s in gspecs]
    DB, DS = aot.distill_batch, aot.distill_len
    for bs in aot.distill_block_sizes:
        def distill_fn(*a, bs=bs):
            ps = list(a[:nP])
            gs = list(a[nP:nP + nG])
            gms = list(a[nP + nG:nP + 2 * nG])
            gvs = list(a[nP + 2 * nG:nP + 3 * nG])
            step, lr, ids = a[nP + 3 * nG:]
            ng, nm, nv, kl = train_mod.distill_step(
                ps, gs, gms, gvs, step, lr, ids, cfg, bs)
            # Anchor every frozen parameter into the graph: the distill
            # loss does not touch the LM head / final layer-post weights,
            # and XLA would otherwise prune those parameters, breaking the
            # manifest's argument contract with the Rust driver.
            anchor = sum(jnp.sum(t) for t in ps) * 0.0
            return tuple(ng) + tuple(nm) + tuple(nv) + (kl + anchor,)

        em.emit(
            f"distill_step_bs{bs}", distill_fn,
            p_args + g_args + gm_args + gv_args +
            [("step", f32()), ("lr", f32()), ("ids", i32(DB, DS))],
            [f"gate:{n}" for n, _ in gspecs] +
            [f"gm:{n}" for n, _ in gspecs] +
            [f"gv:{n}" for n, _ in gspecs] + ["kl"],
        )

    # --- Fig 6 kernel-benchmark family --------------------------------------
    kbench_entries = []
    if not skip_kbench:
        kbs = kb.block_size
        for s in kb.seqlens:
            nblk = s // kbs
            for b in kb.batches:
                em.emit(
                    f"kb_dense_s{s}_b{b}",
                    lambda q, k, v, sl, kbs=kbs: (
                        dense_decode(q, k, v, sl, block_size=kbs),),
                    [("q", f32(b, kb.n_heads, kb.head_dim)),
                     ("k", f32(b, kb.n_kv_heads, s, kb.head_dim)),
                     ("v", f32(b, kb.n_kv_heads, s, kb.head_dim)),
                     ("seq_len", i32(b))],
                    ["out"],
                )
                for sp in kb.sparsities:
                    ksel = max(1, round(nblk * (1.0 - sp)))
                    em.emit(
                        f"kb_sparse_s{s}_b{b}_k{ksel}",
                        lambda q, k, v, idx, sl, kbs=kbs: (
                            block_sparse_decode(q, k, v, idx, sl,
                                                block_size=kbs),),
                        [("q", f32(b, kb.n_heads, kb.head_dim)),
                         ("k", f32(b, kb.n_kv_heads, s, kb.head_dim)),
                         ("v", f32(b, kb.n_kv_heads, s, kb.head_dim)),
                         ("idx", i32(b, kb.n_kv_heads, ksel)),
                         ("seq_len", i32(b))],
                        ["out"],
                    )
                    kbench_entries.append({
                        "seqlen": s, "batch": b, "sparsity": sp,
                        "k_sel": ksel,
                        "dense": f"kb_dense_s{s}_b{b}",
                        "sparse": f"kb_sparse_s{s}_b{b}_k{ksel}",
                    })

    # --- parameters + fixtures ----------------------------------------------
    init_p = params_mod.init_params(cfg)
    init_g = params_mod.init_gate(cfg)
    params_mod.save_flat(os.path.join(out_dir, "model_init.bin"), init_p)
    params_mod.save_flat(os.path.join(out_dir, "gate_init.bin"), init_g)
    write_fixtures(os.path.join(out_dir, "fixtures.json"), cfg, init_g)

    manifest = {
        "model": cfg.to_dict(),
        "aot": aot.to_dict(),
        "kbench": kb.to_dict(),
        "kbench_points": kbench_entries,
        "params": [{"name": n, "shape": list(s)} for n, s in
                   params_mod.param_specs(cfg)],
        "gate_params": [{"name": n, "shape": list(s)} for n, s in
                        params_mod.gate_specs(cfg)],
        "executables": em.manifest_exes,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(em.manifest_exes)} executables")


def write_fixtures(path: str, cfg: ModelConfig, init_g: list):
    """Golden values for the Rust-side gate math (kcomp / gate query /
    scores / oracle GT), computed with the reference implementations."""
    key = jax.random.PRNGKey(42)
    dh, dg = cfg.head_dim, cfg.d_gate
    Hkv, H, g = cfg.n_kv_heads, cfg.n_heads, cfg.group_size
    bs = cfg.block_size
    gd = params_mod.gate_as_dict(cfg, init_g)
    wq_gate = gd["l0.wq_gate"]
    wk_gate = gd["l0.wk_gate"]

    k1, k2, k3, k4 = jax.random.split(key, 4)
    # kcomp: one sequence of 2 blocks.
    k_pre = jax.random.normal(k1, (1, Hkv, 2 * bs, dh))
    kc = gate_mod.k_compress(wk_gate, k_pre, bs, cfg.rope_theta)  # [1,Hkv,2,dg]
    # gate query at position 37.
    q_pre = jax.random.normal(k2, (1, H, dh))
    pos = jnp.array([37], dtype=jnp.int32)
    qg = gate_mod.gate_query(wq_gate, q_pre, pos, cfg.rope_theta)  # [1,Hkv,dg]
    scores = gate_mod.gate_scores(qg, kc)  # [1,Hkv,2]
    # oracle GT for one decode query over S=4 blocks.
    S = 4 * bs
    q_rope = jax.random.normal(k3, (1, H, dh))
    k_rope = jax.random.normal(k4, (1, Hkv, S, dh))
    seq_len = jnp.array([S - 3], dtype=jnp.int32)
    kf = ref.repeat_kv(k_rope, g)
    logits = jnp.einsum("bhd,bhkd->bhk", q_rope, kf) / jnp.sqrt(
        jnp.float32(dh))
    ok = jnp.arange(S)[None, None] < seq_len[:, None, None]
    logits = jnp.where(ok, logits, -1e30)
    e = jnp.exp(logits - logits.max(-1, keepdims=True))
    e = jnp.where(ok, e, 0.0)
    probs = e / e.sum(-1, keepdims=True)
    col = probs.reshape(1, H, S // bs, bs).max(-1)  # [1,H,NBLK]
    gt = col.reshape(1, Hkv, g, S // bs).max(2)  # [1,Hkv,NBLK]

    fx = {
        "config": cfg.to_dict(),
        "kcomp": {
            "k_pre": np.asarray(k_pre).ravel().tolist(),
            "wk_gate": np.asarray(wk_gate).ravel().tolist(),
            "expected_kc": np.asarray(kc).ravel().tolist(),
        },
        "gate_query": {
            "q_pre": np.asarray(q_pre).ravel().tolist(),
            "wq_gate": np.asarray(wq_gate).ravel().tolist(),
            "pos": 37,
            "expected_qg": np.asarray(qg).ravel().tolist(),
            "expected_scores": np.asarray(scores).ravel().tolist(),
        },
        "oracle": {
            "q_rope": np.asarray(q_rope).ravel().tolist(),
            "k_rope": np.asarray(k_rope).ravel().tolist(),
            "seq_len": int(S - 3),
            "expected_gt": np.asarray(gt).ravel().tolist(),
        },
    }
    with open(path, "w") as f:
        json.dump(fx, f)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="lower only the named executables")
    ap.add_argument("--skip-kbench", action="store_true")
    args = ap.parse_args()
    build_all(args.out, DEFAULT_MODEL, DEFAULT_AOT, DEFAULT_KBENCH,
              only=args.only, skip_kbench=args.skip_kbench)


if __name__ == "__main__":
    main()
