"""Fused training steps, AOT-lowered and driven by the Rust trainer.

Each step is one HLO executable doing forward + backward + AdamW update
(the paper trains on MI300x with DeepSpeed ZeRO-2; our single-device analog
is a fused donated-buffer step). The Rust side owns the parameter / Adam
state buffers and the LR schedule (cosine decay, as in the paper §4.1) and
feeds ``lr`` as a scalar each step.

``distill_step`` is the paper's core training contribution (§2.3): the
base model is frozen (stop_gradient), the GT-generating flash kernel
produces the 1D-maxpooled target distribution, and only the AttnGate
parameters receive gradients from the KL loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import gate as gate_mod
from .config import ModelConfig
from .model import forward_train, forward_with_gt

ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8
WEIGHT_DECAY = 0.01


def _adamw_update(params: list, grads: list, ms: list, vs: list,
                  step: jnp.ndarray, lr: jnp.ndarray):
    """AdamW with bias correction; weight decay on matrices only."""
    new_p, new_m, new_v = [], [], []
    t = step + 1.0
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    for p, g, m, v in zip(params, grads, ms, vs):
        m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + ADAM_EPS)
        wd = WEIGHT_DECAY if p.ndim >= 2 else 0.0
        new_p.append(p - lr * (upd + wd * p))
        new_m.append(m2)
        new_v.append(v2)
    return new_p, new_m, new_v


def lm_loss(params: list, cfg: ModelConfig, ids: jnp.ndarray,
            loss_w: jnp.ndarray) -> jnp.ndarray:
    """Weighted next-token cross entropy. ids: [B,S]; loss_w: [B,S]
    (weight for predicting ids[:, t] from position t-1; loss_w[:, 0]
    is ignored)."""
    logits = forward_train(params, cfg, ids)  # [B,S,V]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = ids[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    w = loss_w[:, 1:]
    return (nll * w).sum() / jnp.maximum(w.sum(), 1.0)


def pretrain_step(params: list, ms: list, vs: list, step: jnp.ndarray,
                  lr: jnp.ndarray, ids: jnp.ndarray, loss_w: jnp.ndarray,
                  cfg: ModelConfig):
    """One fused LM training step. Returns (params', ms', vs', loss)."""
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, ids, loss_w))(params)
    new_p, new_m, new_v = _adamw_update(params, grads, ms, vs, step, lr)
    return new_p, new_m, new_v, loss


def gate_forward(gates: list, cfg: ModelConfig, pre_qs: list, pre_ks: list,
                 block_size: int):
    """AttnGate forward for all layers over a full training sequence.

    pre_qs[l]: [B,S,H,dh]; pre_ks[l]: [B,Hkv,S,dh].
    Returns per-layer gate logits [B,S,Hkv,NBLK].
    """
    from .params import gate_as_dict
    gd = gate_as_dict(cfg, gates)
    b, s = pre_qs[0].shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    out = []
    for l in range(cfg.n_layers):
        qg = gate_mod.gate_query(gd[f"l{l}.wq_gate"], pre_qs[l], positions,
                                 cfg.rope_theta)  # [B,S,Hkv,dg]
        kc = gate_mod.k_compress(gd[f"l{l}.wk_gate"], pre_ks[l], block_size,
                                 cfg.rope_theta)  # [B,Hkv,NBLK,dg]
        out.append(gate_mod.gate_scores(qg, kc))  # [B,S,Hkv,NBLK]
    return out


def distill_loss(gates: list, params: list, cfg: ModelConfig,
                 ids: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Self-distillation KL over all layers (frozen base model)."""
    pre_qs, pre_ks, gts = forward_with_gt(params, cfg, ids, block_size)
    pre_qs = [jax.lax.stop_gradient(t) for t in pre_qs]
    pre_ks = [jax.lax.stop_gradient(t) for t in pre_ks]
    gts = [jax.lax.stop_gradient(t) for t in gts]
    logits = gate_forward(gates, cfg, pre_qs, pre_ks, block_size)
    kls = [gate_mod.distill_kl(lg, gt, block_size)
           for lg, gt in zip(logits, gts)]
    return jnp.stack(kls).mean()


def distill_step(params: list, gates: list, gms: list, gvs: list,
                 step: jnp.ndarray, lr: jnp.ndarray, ids: jnp.ndarray,
                 cfg: ModelConfig, block_size: int):
    """One fused AttnGate distillation step (base model frozen).
    Returns (gates', gms', gvs', kl)."""
    kl, grads = jax.value_and_grad(
        lambda g: distill_loss(g, params, cfg, ids, block_size))(gates)
    new_g, new_m, new_v = _adamw_update(gates, grads, gms, gvs, step, lr)
    return new_g, new_m, new_v, kl
