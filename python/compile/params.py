"""Parameter layout contract shared with the Rust coordinator.

Parameters are handled as *flat ordered lists* of f32 tensors: the order
defined by ``param_specs`` / ``gate_specs`` is recorded in
``artifacts/manifest.json`` and mirrored by ``rust/src/model/params.rs``.
Checkpoints are raw little-endian f32 concatenations in that order.
"""

from __future__ import annotations

import struct

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig


def param_specs(cfg: ModelConfig) -> list:
    """Ordered (name, shape) list for the base model parameters."""
    d, dh = cfg.d_model, cfg.head_dim
    specs = [("emb", (cfg.vocab, d))]
    for l in range(cfg.n_layers):
        specs += [
            (f"l{l}.wq", (d, cfg.n_heads * dh)),
            (f"l{l}.wk", (d, cfg.n_kv_heads * dh)),
            (f"l{l}.wv", (d, cfg.n_kv_heads * dh)),
            (f"l{l}.wo", (cfg.n_heads * dh, d)),
            (f"l{l}.w1", (d, cfg.mlp_hidden)),
            (f"l{l}.w2", (cfg.mlp_hidden, d)),
            (f"l{l}.ln1", (d,)),
            (f"l{l}.ln2", (d,)),
        ]
    specs += [("ln_f", (d,)), ("head", (d, cfg.vocab))]
    return specs


def gate_specs(cfg: ModelConfig) -> list:
    """Ordered (name, shape) list for the AttnGate parameters (§2.2):
    per-KV-head query aggregation + pooled-K projection."""
    g, dh, dg = cfg.group_size, cfg.head_dim, cfg.d_gate
    specs = []
    for l in range(cfg.n_layers):
        specs += [
            (f"l{l}.wq_gate", (cfg.n_kv_heads, g * dh, dg)),
            (f"l{l}.wk_gate", (cfg.n_kv_heads, 3 * dh, dg)),
        ]
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> list:
    """Initialise base-model parameters (list in param_specs order)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2")) or name == "ln_f":
            out.append(jnp.ones(shape, dtype=jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 0.02 if name in ("emb", "head") else 1.0 / np.sqrt(fan_in)
            out.append(std * jax.random.normal(sub, shape, dtype=jnp.float32))
    return out


def init_gate(cfg: ModelConfig, seed: int = 1) -> list:
    """Initialise AttnGate parameters (list in gate_specs order)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for _, shape in gate_specs(cfg):
        key, sub = jax.random.split(key)
        std = 1.0 / np.sqrt(shape[-2])
        out.append(std * jax.random.normal(sub, shape, dtype=jnp.float32))
    return out


def as_dict(cfg: ModelConfig, flat: list) -> dict:
    return {name: t for (name, _), t in zip(param_specs(cfg), flat)}


def gate_as_dict(cfg: ModelConfig, flat: list) -> dict:
    return {name: t for (name, _), t in zip(gate_specs(cfg), flat)}


def save_flat(path: str, flat: list) -> None:
    """Raw little-endian f32 concatenation in spec order."""
    with open(path, "wb") as f:
        for t in flat:
            f.write(np.asarray(t, dtype="<f4").tobytes())


def load_flat(path: str, specs: list) -> list:
    out = []
    with open(path, "rb") as f:
        for _, shape in specs:
            n = int(np.prod(shape))
            buf = f.read(4 * n)
            assert len(buf) == 4 * n, "truncated checkpoint"
            out.append(jnp.asarray(np.frombuffer(buf, dtype="<f4").reshape(shape)))
    return out
