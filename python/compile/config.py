"""Shared model / gate configuration.

This is the single source of truth for the architecture contract between the
Python compile path (L1 Pallas kernels + L2 JAX model, AOT-lowered to HLO
text) and the Rust coordinator (L3), which reads the same values from
``artifacts/manifest.json``.

The configuration mirrors the paper's Qwen3-style GQA transformer, scaled to
the CPU testbed (see DESIGN.md §1 for the scale mapping):

  * GQA with ``n_heads`` query heads sharing ``n_kv_heads`` KV heads
    (group size g = n_heads // n_kv_heads, paper: g=8, ours: g=4).
  * RoPE positional embedding, pre-RoPE Q/K feeding the AttnGate (§2.2).
  * AttnGate with per-KV-head query aggregation (W_q_gate: [g*head_dim,
    d_gate]) and {max,min,avg}-pooled K compression (W_k_gate: [3*head_dim,
    d_gate]).
"""

from __future__ import annotations

import dataclasses
import json


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of the base GQA transformer + AttnGate dimensions."""

    vocab: int = 256
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 2
    head_dim: int = 32
    mlp_hidden: int = 512
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    # AttnGate
    d_gate: int = 32
    # Default sparse attention block size (tokens per block). The paper's
    # default is 64 at 32k contexts; ours is 16 at 512 contexts (same
    # blocks-per-context ratio). Ablations sweep {8, 16, 32, 64}.
    block_size: int = 16
    # Maximum sequence length supported by the decode path artifacts.
    max_seq: int = 512

    @property
    def group_size(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    @property
    def n_blocks(self) -> int:
        return self.max_seq // self.block_size

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["group_size"] = self.group_size
        return d

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)


@dataclasses.dataclass(frozen=True)
class AotConfig:
    """Shapes baked into the AOT-lowered executables.

    Every executable has fully static shapes (XLA requirement); the Rust
    coordinator pads its runtime state to these shapes. ``manifest.json``
    records the instantiated variants.
    """

    # Decode/serving batch (requests are padded up to this).
    decode_batch: int = 8
    # Prefill sequence length (prompts padded).
    prefill_len: int = 512
    # layer_post_sel variants: number of *selected tokens* (budget * block)
    # the sparse attention executable consumes. Covers every (block size,
    # block budget) pair used in the experiments.
    sel_token_variants: tuple = (64, 128, 192, 256, 384)
    # Training step shapes.
    train_batch: int = 4
    train_len: int = 512
    # Distillation step block sizes (Fig 7 ablation retrains the gate per
    # block size).
    distill_block_sizes: tuple = (8, 16, 32, 64)
    distill_batch: int = 4
    distill_len: int = 512

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["sel_token_variants"] = list(self.sel_token_variants)
        d["distill_block_sizes"] = list(self.distill_block_sizes)
        return d


@dataclasses.dataclass(frozen=True)
class KernelBenchConfig:
    """Fig 6 kernel-benchmark family: the paper sweeps seqlen x batch x
    sparsity at GQA 64/8 heads, head_dim 128, block 64. We keep block 64
    and the same GQA *group size ratio* while scaling head counts to the
    CPU testbed."""

    n_heads: int = 8
    n_kv_heads: int = 2
    head_dim: int = 32
    block_size: int = 64
    seqlens: tuple = (1024, 2048, 4096, 8192)
    batches: tuple = (1, 4)
    sparsities: tuple = (0.5, 0.7, 0.8, 0.9)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        for k in ("seqlens", "batches", "sparsities"):
            d[k] = list(d[k])
        return d


DEFAULT_MODEL = ModelConfig()
DEFAULT_AOT = AotConfig()
DEFAULT_KBENCH = KernelBenchConfig()
