"""Block-sparse flash-decoding kernel (paper §3.3) and its dense baseline.

The paper implements this in TileLang for H100: grid over (batch, heads_kv,
num_split), wgmma with the GQA query group padded to 64 rows, traversal of
the AttnGate-selected block-index list, split-K load balancing over
``max_selected_blocks``.

Pallas/TPU-style adaptation (DESIGN.md §6):
  * grid = (batch, heads_kv): each program owns one GQA group. The whole
    group of ``g`` query rows stays resident as a [g, D] tile and is
    matmul'd against each selected [block, D] K tile — the MXU-shaped
    analog of the paper's wgmma group padding (arithmetic intensity comes
    from the shared-sparsity group dimension, the paper's core hardware
    point).
  * the index list is streamed with a ``fori_loop``; padding entries
    (idx < 0) contribute nothing (their logits are masked to -inf). The
    loop trip count is the *compile-time* ``max_selected_blocks``, so cost
    scales with the budget, exactly like the paper's kernel skipping
    unselected blocks.
  * ``num_split`` is unnecessary on the CPU interpret path (XLA
    parallelises over the grid); on a real TPU the same kernel would add a
    third grid axis over splits of the index list.

Both kernels are lowered standalone (via aot.py) into the Fig 6 benchmark
executables, and the sparse kernel backs the serving engine's fused decode
ablation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _sparse_decode_kernel(q_ref, k_ref, v_ref, idx_ref, len_ref, o_ref, *,
                          block_size: int, max_sel: int, group: int,
                          head_dim: int):
    """Grid: (B, Hkv). q_ref: [1, g, D] (the GQA group); k/v_ref:
    [1, 1, S, D]; idx_ref: [1, 1, MAXSEL] int32; len_ref: [1] int32."""
    q = q_ref[0]  # [g, D]
    seq_len = len_ref[0]
    scale = 1.0 / (head_dim ** 0.5)

    m0 = jnp.full((group,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((group,), dtype=jnp.float32)
    acc0 = jnp.zeros((group, head_dim), dtype=jnp.float32)

    def body(i, carry):
        m, l, acc = carry
        j = idx_ref[0, 0, i]
        valid_blk = j >= 0
        jc = jnp.maximum(j, 0)
        k_blk = k_ref[0, 0, pl.ds(jc * block_size, block_size), :]
        v_blk = v_ref[0, 0, pl.ds(jc * block_size, block_size), :]
        logits = jnp.dot(q, k_blk.T) * scale  # [g, block]
        k_pos = jc * block_size + jax.lax.iota(jnp.int32, block_size)
        ok = valid_blk & (k_pos < seq_len)  # [block]
        logits = jnp.where(ok[None, :], logits, NEG_INF)
        blk_max = logits.max(axis=1)
        m_new = jnp.maximum(m, blk_max)
        shift = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
        p = jnp.exp(logits - shift[:, None])
        p = jnp.where(ok[None, :], p, 0.0)
        corr = jnp.where(m > NEG_INF / 2, jnp.exp(m - shift), 0.0)
        l_new = l * corr + p.sum(axis=1)
        acc_new = acc * corr[:, None] + jnp.dot(p, v_blk)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, max_sel, body, (m0, l0, acc0))
    o_ref[0] = acc / jnp.maximum(l, 1e-30)[:, None]


@functools.partial(jax.jit, static_argnames=("block_size",))
def block_sparse_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        idx: jnp.ndarray, seq_len: jnp.ndarray, *,
                        block_size: int) -> jnp.ndarray:
    """Block-sparse GQA decode attention for one generated token.

    q: [B, H, D]; k, v: [B, Hkv, S, D]; idx: [B, Hkv, MAXSEL] int32
    (-1 padded, shared within each GQA group); seq_len: [B] int32.
    Returns out [B, H, D].
    """
    b, h, d = q.shape
    _, hkv, s, _ = k.shape
    group = h // hkv
    max_sel = idx.shape[-1]
    assert s % block_size == 0
    kernel = functools.partial(_sparse_decode_kernel, block_size=block_size,
                               max_sel=max_sel, group=group, head_dim=d)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv),
        in_specs=[
            pl.BlockSpec((1, group, d), lambda bb, kh: (bb, kh, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bb, kh: (bb, kh, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bb, kh: (bb, kh, 0, 0)),
            pl.BlockSpec((1, 1, max_sel), lambda bb, kh: (bb, kh, 0)),
            pl.BlockSpec((1,), lambda bb, kh: (bb,)),
        ],
        out_specs=pl.BlockSpec((1, group, d), lambda bb, kh: (bb, kh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        interpret=True,
    )(q, k, v, idx, seq_len)
    return out


def _dense_decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *,
                         block_size: int, n_blocks: int, group: int,
                         head_dim: int):
    """Dense flash-decode baseline (FA3 analog): identical streaming loop,
    but over *all* KV blocks — no index list, no skip."""
    q = q_ref[0]
    seq_len = len_ref[0]
    scale = 1.0 / (head_dim ** 0.5)

    m0 = jnp.full((group,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((group,), dtype=jnp.float32)
    acc0 = jnp.zeros((group, head_dim), dtype=jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        k_blk = k_ref[0, 0, pl.ds(j * block_size, block_size), :]
        v_blk = v_ref[0, 0, pl.ds(j * block_size, block_size), :]
        logits = jnp.dot(q, k_blk.T) * scale
        k_pos = j * block_size + jax.lax.iota(jnp.int32, block_size)
        ok = k_pos < seq_len
        logits = jnp.where(ok[None, :], logits, NEG_INF)
        blk_max = logits.max(axis=1)
        m_new = jnp.maximum(m, blk_max)
        shift = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
        p = jnp.exp(logits - shift[:, None])
        p = jnp.where(ok[None, :], p, 0.0)
        corr = jnp.where(m > NEG_INF / 2, jnp.exp(m - shift), 0.0)
        l_new = l * corr + p.sum(axis=1)
        acc_new = acc * corr[:, None] + jnp.dot(p, v_blk)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[0] = acc / jnp.maximum(l, 1e-30)[:, None]


@functools.partial(jax.jit, static_argnames=("block_size",))
def dense_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 seq_len: jnp.ndarray, *, block_size: int) -> jnp.ndarray:
    """Dense GQA flash-decode baseline. Same signature as the sparse kernel
    minus the index list."""
    b, h, d = q.shape
    _, hkv, s, _ = k.shape
    group = h // hkv
    assert s % block_size == 0
    kernel = functools.partial(_dense_decode_kernel, block_size=block_size,
                               n_blocks=s // block_size, group=group,
                               head_dim=d)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv),
        in_specs=[
            pl.BlockSpec((1, group, d), lambda bb, kh: (bb, kh, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bb, kh: (bb, kh, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bb, kh: (bb, kh, 0, 0)),
            pl.BlockSpec((1,), lambda bb, kh: (bb,)),
        ],
        out_specs=pl.BlockSpec((1, group, d), lambda bb, kh: (bb, kh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        interpret=True,
    )(q, k, v, seq_len)
    return out
