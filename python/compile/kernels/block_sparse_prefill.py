"""Block-sparse flash *prefill* kernel — the original SeerAttention
setting and the paper's §6.3 unification direction.

Where the decode kernel (block_sparse_decode.py) processes one query
token against a selected KV block list, the prefill kernel processes a
whole prompt with a *2D* block mask: for each (query-block, key-block)
pair, a boolean activation from the prefill AttnGate decides whether the
tile is computed or skipped. Causal structure is composed with the mask
(upper-triangle tiles are never computed; the diagonal tile is always
active, mirroring the decode path's always-on partial block).

Same streaming (online-softmax) structure and interpret=True lowering as
the other kernels; checked against the masked reference in ref.py by
python/tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _sparse_prefill_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *,
                           block_q: int, block_k: int, seq_len: int,
                           head_dim: int):
    """Grid: (B, H, S // block_q). mask_ref: [1, 1, nqb, nkb] f32 (>0 =>
    compute the tile); causality is enforced inside regardless."""
    qi = pl.program_id(2)
    q = q_ref[0, 0]  # [block_q, D]
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    nkb = seq_len // block_k
    scale = 1.0 / (head_dim ** 0.5)

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, head_dim), dtype=jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        active = mask_ref[0, 0, qi, j] > 0.0
        k_blk = k_ref[0, 0, pl.ds(j * block_k, block_k), :]
        v_blk = v_ref[0, 0, pl.ds(j * block_k, block_k), :]
        logits = jnp.dot(q, k_blk.T) * scale
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        ok = (q_pos[:, None] >= k_pos[None, :]) & active
        logits = jnp.where(ok, logits, NEG_INF)
        blk_max = logits.max(axis=1)
        m_new = jnp.maximum(m, blk_max)
        shift = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
        p = jnp.where(ok, jnp.exp(logits - shift[:, None]), 0.0)
        corr = jnp.where(m > NEG_INF / 2, jnp.exp(m - shift), 0.0)
        return (m_new, l * corr + p.sum(axis=1),
                acc * corr[:, None] + jnp.dot(p, v_blk))

    m, l, acc = jax.lax.fori_loop(0, nkb, body, (m0, l0, acc0))
    o_ref[0, 0] = acc / jnp.maximum(l, 1e-30)[:, None]


@functools.partial(jax.jit, static_argnames=("group", "block_q", "block_k"))
def block_sparse_prefill(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         block_mask: jnp.ndarray, *, group: int,
                         block_q: int, block_k: int) -> jnp.ndarray:
    """Causal GQA attention with a 2D block-activation mask.

    q: [B, H, S, D]; k, v: [B, Hkv, S, D]; block_mask:
    [B, Hkv, S//block_q, S//block_k] f32 (shared within the GQA group).
    Returns out [B, H, S, D]. Rows whose causal+masked tile set is empty
    yield zeros (callers always activate the diagonal in practice).
    """
    b, h, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0
    kernel = functools.partial(_sparse_prefill_kernel, block_q=block_q,
                               block_k=block_k, seq_len=s, head_dim=d)
    nqb, nkb = s // block_q, s // block_k
    return pl.pallas_call(
        kernel,
        grid=(b, h, nqb),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bb, hh, qq: (bb, hh, qq, 0)),
            pl.BlockSpec((1, 1, s, d),
                         lambda bb, hh, qq, group=group: (bb, hh // group, 0, 0)),
            pl.BlockSpec((1, 1, s, d),
                         lambda bb, hh, qq, group=group: (bb, hh // group, 0, 0)),
            pl.BlockSpec((1, 1, nqb, nkb),
                         lambda bb, hh, qq, group=group: (bb, hh // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, hh, qq: (bb, hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
        interpret=True,
    )(q, k, v, block_mask)
