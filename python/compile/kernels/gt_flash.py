"""Ground-truth-generating FlashAttention forward kernel (paper Fig 2b).

A Pallas port of the paper's modified FlashAttention-2 training kernel: a
standard streaming (online-softmax) causal attention forward that *also*
emits, for every query row, the column-block max-pooled attention scores —
the distillation ground truth for the AttnGate — by reusing the running
row-max/row-sum statistics instead of materialising the O(S^2) map.

For a query row t with final running max m_t and sum l_t, the max attention
probability inside K-block j is

    gt[t, j] = exp(max_logit_block_j(t) - m_t) / l_t

which is exactly ``max_{k in block j} softmax(qK^T)[t, k]``: the kernel only
has to track the per-block max logit alongside the usual flash statistics.

Hardware adaptation (DESIGN.md §6): the K-tile equals the AttnGate block
size, the query tile keeps the whole GQA story at L2 (group max happens
outside), and the kernel is lowered with ``interpret=True`` so it becomes
plain HLO the CPU PJRT client can run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _gt_flash_kernel(q_ref, k_ref, v_ref, o_ref, gt_ref, *, block_q: int,
                     block_k: int, seq_len: int, head_dim: int):
    """Grid: (B, H, S // block_q). K/V refs hold the full [S, D] slice of
    the matching KV head; the loop below streams over K blocks."""
    qi = pl.program_id(2)
    q = q_ref[0, 0]  # [block_q, D]
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)  # [block_q]
    nblk = seq_len // block_k
    scale = 1.0 / (head_dim ** 0.5)

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, head_dim), dtype=jnp.float32)
    mb0 = jnp.full((block_q, nblk), NEG_INF, dtype=jnp.float32)

    def body(j, carry):
        m, l, acc, mb = carry
        k_blk = k_ref[0, 0, pl.ds(j * block_k, block_k), :]  # [block_k, D]
        v_blk = v_ref[0, 0, pl.ds(j * block_k, block_k), :]
        logits = jnp.dot(q, k_blk.T) * scale  # [block_q, block_k]
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        causal = q_pos[:, None] >= k_pos[None, :]
        logits = jnp.where(causal, logits, NEG_INF)
        blk_max = logits.max(axis=1)  # [block_q]
        mb = mb.at[:, j].set(blk_max)
        m_new = jnp.maximum(m, blk_max)
        # Guard fully-masked rows: keep exp argument finite.
        shift = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
        p = jnp.exp(logits - shift[:, None])
        p = jnp.where(causal, p, 0.0)
        correction = jnp.where(m > NEG_INF / 2, jnp.exp(m - shift), 0.0)
        l_new = l * correction + p.sum(axis=1)
        acc_new = acc * correction[:, None] + jnp.dot(p, v_blk)
        return m_new, l_new, acc_new, mb

    m, l, acc, mb = jax.lax.fori_loop(0, nblk, body, (m0, l0, acc0, mb0))
    l_safe = jnp.maximum(l, 1e-30)
    o_ref[0, 0] = acc / l_safe[:, None]
    shift = jnp.where(m > NEG_INF / 2, m, 0.0)
    gt = jnp.where(mb > NEG_INF / 2,
                   jnp.exp(mb - shift[:, None]) / l_safe[:, None], 0.0)
    gt_ref[0, 0] = gt


@functools.partial(jax.jit, static_argnames=("group", "block_k", "block_q"))
def gt_flash(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, group: int,
             block_k: int, block_q: int = 64):
    """Causal GQA flash attention that also returns GT block scores.

    q: [B, H, S, D]; k, v: [B, Hkv, S, D], H = Hkv * group.
    Returns (out [B, H, S, D], gt [B, H, S, S // block_k]).
    ``gt`` is per *query head*; the GQA group max + normalisation live in
    the caller (see ref.gt_block_scores_ref / gate.distill_targets).
    """
    b, h, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0
    nblk = s // block_k
    kernel = functools.partial(_gt_flash_kernel, block_q=block_q,
                               block_k=block_k, seq_len=s, head_dim=d)
    out, gt = pl.pallas_call(
        kernel,
        grid=(b, h, s // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bb, hh, qq: (bb, hh, qq, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bb, hh, qq, group=group: (bb, hh // group, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bb, hh, qq, group=group: (bb, hh // group, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bb, hh, qq: (bb, hh, qq, 0)),
            pl.BlockSpec((1, 1, block_q, nblk), lambda bb, hh, qq: (bb, hh, qq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s, nblk), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
    return out, gt
