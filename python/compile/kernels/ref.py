"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package is checked against the corresponding
function here by ``python/tests``. These references are deliberately naive
(O(S^2) materialised attention maps) — clarity over speed.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def repeat_kv(x: jnp.ndarray, group: int) -> jnp.ndarray:
    """[B, Hkv, S, D] -> [B, Hkv*group, S, D] by repeating each KV head."""
    b, hkv, s, d = x.shape
    x = jnp.broadcast_to(x[:, :, None], (b, hkv, group, s, d))
    return x.reshape(b, hkv * group, s, d)


def causal_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         group: int):
    """Naive causal GQA attention.

    q: [B, H, S, D]; k, v: [B, Hkv, S, D] with H == Hkv * group.
    Returns (out [B, H, S, D], probs [B, H, S, S]).
    """
    b, h, s, d = q.shape
    kf = repeat_kv(k, group)
    vf = repeat_kv(v, group)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kf) / jnp.sqrt(jnp.float32(d))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out, probs


def gt_block_scores_ref(probs: jnp.ndarray, block_size: int,
                        group: int) -> jnp.ndarray:
    """Ground-truth block scores (paper §2.3): column-wise 1D max-pool of
    the attention map per block, then max over each GQA query-head group.

    probs: [B, H, S, S] -> gt [B, Hkv, S, NBLK] (unnormalised, unmasked:
    includes the query's own partial block; masking/normalisation to the
    *complete preceding blocks* happens in the caller, matching the decode
    AttnGate which only scores complete blocks).
    """
    b, h, s, _ = probs.shape
    nblk = s // block_size
    p = probs.reshape(b, h, s, nblk, block_size)
    col = p.max(-1)  # [B, H, S, NBLK]
    hkv = h // group
    colg = col.reshape(b, hkv, group, s, nblk).max(2)
    return colg


def normalize_gt(gt: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Mask GT to complete preceding blocks (j < t // block) and normalise
    each row to sum 1 (rows with no valid block stay all-zero)."""
    b, hkv, s, nblk = gt.shape
    t = jnp.arange(s)[:, None]
    j = jnp.arange(nblk)[None, :]
    valid = (j < t // block_size).astype(gt.dtype)  # [S, NBLK]
    gt = gt * valid[None, None]
    denom = gt.sum(-1, keepdims=True)
    return jnp.where(denom > 0, gt / jnp.maximum(denom, 1e-30), 0.0)


def sparse_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      idx: jnp.ndarray, seq_len: jnp.ndarray,
                      block_size: int) -> jnp.ndarray:
    """Naive block-sparse decode attention (single query token).

    q: [B, H, D]; k, v: [B, Hkv, S, D]; idx: [B, Hkv, MAXSEL] int32 block
    indices, -1 = padding; seq_len: [B] int32 valid KV length.
    Sparsity is shared within each GQA group (paper §2.2).
    Returns out [B, H, D].
    """
    b, h, d = q.shape
    _, hkv, s, _ = k.shape
    group = h // hkv
    nblk = s // block_size
    # Token-level mask from the selected block indices.
    blk_sel = jnp.zeros((b, hkv, nblk), dtype=bool)
    bi = jnp.arange(b)[:, None, None]
    hi = jnp.arange(hkv)[None, :, None]
    safe_idx = jnp.clip(idx, 0, nblk - 1)
    blk_sel = blk_sel.at[bi, hi, safe_idx].max(idx >= 0)
    tok_sel = jnp.repeat(blk_sel, block_size, axis=-1)  # [B, Hkv, S]
    in_len = jnp.arange(s)[None] < seq_len[:, None]  # [B, S]
    tok_mask = tok_sel & in_len[:, None]
    kf = repeat_kv(k, group)
    vf = repeat_kv(v, group)
    maskf = jnp.repeat(tok_mask, group, axis=1)  # [B, H, S]
    logits = jnp.einsum("bhd,bhkd->bhk", q, kf) / jnp.sqrt(jnp.float32(d))
    logits = jnp.where(maskf, logits, NEG_INF)
    m = logits.max(-1, keepdims=True)
    e = jnp.exp(logits - m) * maskf
    l = jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhk,bhkd->bhd", e / l, vf)


def dense_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     seq_len: jnp.ndarray) -> jnp.ndarray:
    """Naive dense decode attention (FlashAttention-3-baseline analog)."""
    b, h, d = q.shape
    _, hkv, s, _ = k.shape
    group = h // hkv
    kf = repeat_kv(k, group)
    vf = repeat_kv(v, group)
    logits = jnp.einsum("bhd,bhkd->bhk", q, kf) / jnp.sqrt(jnp.float32(d))
    in_len = jnp.arange(s)[None, None] < seq_len[:, None, None]
    logits = jnp.where(in_len, logits, NEG_INF)
    m = logits.max(-1, keepdims=True)
    e = jnp.exp(logits - m) * in_len
    l = jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhk,bhkd->bhd", e / l, vf)


def sparse_prefill_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       block_mask: jnp.ndarray, block_q: int,
                       block_k: int) -> jnp.ndarray:
    """Naive causal GQA attention with a 2D block-activation mask (the
    block_sparse_prefill oracle). block_mask: [B, Hkv, nqb, nkb]."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    kf = repeat_kv(k, group)
    vf = repeat_kv(v, group)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kf) / jnp.sqrt(jnp.float32(d))
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    tile = jnp.repeat(jnp.repeat(block_mask > 0, block_q, axis=2),
                      block_k, axis=3)  # [B, Hkv, S, S]
    tile = jnp.repeat(tile, group, axis=1)  # [B, H, S, S]
    ok = causal[None, None] & tile
    logits = jnp.where(ok, logits, NEG_INF)
    m = logits.max(-1, keepdims=True)
    m = jnp.where(m > NEG_INF / 2, m, 0.0)
    e = jnp.where(ok, jnp.exp(logits - m), 0.0)
    l = jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", e / l, vf)
