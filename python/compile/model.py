"""L2: the GQA transformer (Qwen3-style, scaled) and its decode-path pieces.

The model is expressed as pure functions over *flat ordered parameter
lists* (see params.py) so that every function AOT-lowers to an HLO
executable with a stable, manifest-documented argument order.

Decode is split per layer (DESIGN.md §2): ``layer_pre`` produces Q/K/V and
the gate query for one token; the Rust coordinator then scores blocks,
selects them (budget/threshold/quest/oracle policy) and gathers the
selected KV; ``layer_post_sel`` consumes the gathered blocks. This mirrors
a paged-KV serving system where page selection is host-side.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import gate as gate_mod
from .config import ModelConfig
from .kernels.gt_flash import gt_flash
from .kernels import ref
from .params import as_dict
from .rope import apply_rope

NEG_INF = -1e30


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def mlp(x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray) -> jnp.ndarray:
    h = x @ w1
    return (h * jax.nn.sigmoid(h)) @ w2  # SiLU


# ---------------------------------------------------------------------------
# Full-sequence forward (training / prefill)
# ---------------------------------------------------------------------------

def _qkv(p: dict, l: int, cfg: ModelConfig, x: jnp.ndarray,
         positions: jnp.ndarray):
    """Project one layer's Q/K/V for a full sequence.

    x: [B, S, d]; positions: [B, S] int32. Returns
    (q_rope [B,H,S,dh], k_rope [B,Hkv,S,dh], v [B,Hkv,S,dh],
     q_pre [B,S,H,dh], k_pre [B,S,Hkv,dh]).
    """
    b, s, _ = x.shape
    dh = cfg.head_dim
    xn = rmsnorm(x, p[f"l{l}.ln1"], cfg.rms_eps)
    q = (xn @ p[f"l{l}.wq"]).reshape(b, s, cfg.n_heads, dh)
    k = (xn @ p[f"l{l}.wk"]).reshape(b, s, cfg.n_kv_heads, dh)
    v = (xn @ p[f"l{l}.wv"]).reshape(b, s, cfg.n_kv_heads, dh)
    q_rope = apply_rope(q, positions[..., None], cfg.rope_theta)
    k_rope = apply_rope(k, positions[..., None], cfg.rope_theta)
    to_hsd = lambda t: jnp.transpose(t, (0, 2, 1, 3))
    return to_hsd(q_rope), to_hsd(k_rope), to_hsd(v), q, k


def _finish_layer(p: dict, l: int, cfg: ModelConfig, x: jnp.ndarray,
                  attn_out_hsd: jnp.ndarray) -> jnp.ndarray:
    """attn_out_hsd: [B, H, S, dh] -> wo -> residual -> MLP block."""
    b, h, s, dh = attn_out_hsd.shape
    attn = jnp.transpose(attn_out_hsd, (0, 2, 1, 3)).reshape(b, s, h * dh)
    x = x + attn @ p[f"l{l}.wo"]
    return x + mlp(rmsnorm(x, p[f"l{l}.ln2"], cfg.rms_eps),
                   p[f"l{l}.w1"], p[f"l{l}.w2"])


def forward_train(params: list, cfg: ModelConfig,
                  ids: jnp.ndarray) -> jnp.ndarray:
    """Dense causal forward for pretraining. ids: [B, S] -> logits [B,S,V]."""
    p = as_dict(cfg, params)
    b, s = ids.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = p["emb"][ids]
    for l in range(cfg.n_layers):
        q, k, v, _, _ = _qkv(p, l, cfg, x, positions)
        out, _ = ref.causal_attention_ref(q, k, v, cfg.group_size)
        x = _finish_layer(p, l, cfg, x, out)
    xn = rmsnorm(x, p["ln_f"], cfg.rms_eps)
    return xn @ p["head"]


def forward_with_gt(params: list, cfg: ModelConfig, ids: jnp.ndarray,
                    block_size: int):
    """Frozen-model forward through the GT-generating flash kernel
    (paper Fig 2): returns per-layer distillation inputs.

    Returns (pre_q [L][B,S,H,dh], pre_k [L][B,Hkv,S,dh],
             gt_norm [L][B,Hkv,S,NBLK]).

    The base model is *frozen* during distillation (§2.3): gradients are
    stopped at the parameters, which also keeps the non-differentiable
    GT flash kernel off every autodiff path.
    """
    params = [jax.lax.stop_gradient(t) for t in params]
    p = as_dict(cfg, params)
    b, s = ids.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = p["emb"][ids]
    pre_qs, pre_ks, gts = [], [], []
    for l in range(cfg.n_layers):
        q, k, v, q_pre, k_pre = _qkv(p, l, cfg, x, positions)
        out, gt_h = gt_flash(q, k, v, group=cfg.group_size,
                             block_k=block_size)
        nblk = s // block_size
        gt = gt_h.reshape(b, cfg.n_kv_heads, cfg.group_size, s, nblk).max(2)
        gts.append(ref.normalize_gt(gt, block_size))
        pre_qs.append(q_pre)
        pre_ks.append(jnp.transpose(k_pre, (0, 2, 1, 3)))
        x = _finish_layer(p, l, cfg, x, out)
    return pre_qs, pre_ks, gts


def prefill(params: list, cfg: ModelConfig, ids: jnp.ndarray,
            seq_len: jnp.ndarray):
    """Dense prefill that materialises the decode-time caches.

    ids: [B, S]; seq_len: [B] int32 (positions >= seq_len are padding).
    Returns (logits [B,S,V], k_rope [L,B,Hkv,S,dh], v [L,B,Hkv,S,dh],
             k_pre [L,B,Hkv,S,dh]).
    The Rust side builds the K compression cache from k_pre (it owns the
    gate weights) and reads logits at seq_len-1 to sample the first
    generated token.
    """
    p = as_dict(cfg, params)
    b, s = ids.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = p["emb"][ids]
    k_caches, v_caches, kpre_caches = [], [], []
    for l in range(cfg.n_layers):
        q, k, v, _, k_pre = _qkv(p, l, cfg, x, positions)
        # Mask padded keys so they never receive attention.
        kmask = (jnp.arange(s)[None] < seq_len[:, None])  # [B, S]
        kf = ref.repeat_kv(k, cfg.group_size)
        vf = ref.repeat_kv(v, cfg.group_size)
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, kf) / jnp.sqrt(
            jnp.float32(cfg.head_dim))
        causal = jnp.tril(jnp.ones((s, s), dtype=bool))
        ok = causal[None, None] & kmask[:, None, None, :]
        logits = jnp.where(ok, logits, NEG_INF)
        m = logits.max(-1, keepdims=True)
        e = jnp.exp(logits - m)
        e = jnp.where(ok, e, 0.0)
        probs = e / jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
        out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
        k_caches.append(k)
        v_caches.append(v)
        kpre_caches.append(jnp.transpose(k_pre, (0, 2, 1, 3)))
        x = _finish_layer(p, l, cfg, x, out)
    xn = rmsnorm(x, p["ln_f"], cfg.rms_eps)
    logits = xn @ p["head"]
    return (logits, jnp.stack(k_caches), jnp.stack(v_caches),
            jnp.stack(kpre_caches))


# ---------------------------------------------------------------------------
# Decode-path per-layer pieces (one token per sequence)
# ---------------------------------------------------------------------------

def layer_pre(x: jnp.ndarray, pos: jnp.ndarray, wq: jnp.ndarray,
              wk: jnp.ndarray, wv: jnp.ndarray, ln1: jnp.ndarray,
              wq_gate: jnp.ndarray, cfg: ModelConfig):
    """One layer's projections for a single decode token.

    x: [B, d]; pos: [B] int32.
    Returns (q_rope [B,H,dh], k_rope [B,Hkv,dh], v [B,Hkv,dh],
             k_pre [B,Hkv,dh], q_gate [B,Hkv,dg]).
    k_rope/v extend the Rust-owned KV cache; k_pre feeds the K compression
    cache update; q_gate scores blocks for this token.
    """
    b, _ = x.shape
    dh = cfg.head_dim
    xn = rmsnorm(x, ln1, cfg.rms_eps)
    q = (xn @ wq).reshape(b, cfg.n_heads, dh)
    k = (xn @ wk).reshape(b, cfg.n_kv_heads, dh)
    v = (xn @ wv).reshape(b, cfg.n_kv_heads, dh)
    q_rope = apply_rope(q, pos[:, None], cfg.rope_theta)
    k_rope = apply_rope(k, pos[:, None], cfg.rope_theta)
    q_gate = gate_mod.gate_query(wq_gate, q, pos, cfg.rope_theta)
    return q_rope, k_rope, v, k, q_gate


def layer_post_sel(q_rope: jnp.ndarray, k_sel: jnp.ndarray,
                   v_sel: jnp.ndarray, sel_mask: jnp.ndarray,
                   resid: jnp.ndarray, wo: jnp.ndarray, w1: jnp.ndarray,
                   w2: jnp.ndarray, ln2: jnp.ndarray, cfg: ModelConfig):
    """Sparse attention over Rust-gathered KV blocks + rest of the layer.

    q_rope: [B, H, dh]; k_sel/v_sel: [B, Hkv, T, dh] (T = selected tokens,
    gathered + padded by the coordinator); sel_mask: [B, Hkv, T] (1 valid);
    resid: [B, d] (the layer input). Returns x' [B, d].
    """
    b, h, dh = q_rope.shape
    hkv = cfg.n_kv_heads
    g = cfg.group_size
    qg = q_rope.reshape(b, hkv, g, dh)
    logits = jnp.einsum("bkgd,bktd->bkgt", qg, k_sel) / jnp.sqrt(
        jnp.float32(dh))
    ok = sel_mask[:, :, None, :] > 0
    logits = jnp.where(ok, logits, NEG_INF)
    m = logits.max(-1, keepdims=True)
    m = jnp.where(m > NEG_INF / 2, m, 0.0)
    e = jnp.where(ok, jnp.exp(logits - m), 0.0)
    probs = e / jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    attn = jnp.einsum("bkgt,bktd->bkgd", probs, v_sel).reshape(b, h * dh)
    x = resid + attn @ wo
    return x + mlp(rmsnorm(x, ln2, cfg.rms_eps), w1, w2)


def layer_post_sel_perhead(q_rope: jnp.ndarray, k_sel: jnp.ndarray,
                           v_sel: jnp.ndarray, sel_mask: jnp.ndarray,
                           resid: jnp.ndarray, wo: jnp.ndarray,
                           w1: jnp.ndarray, w2: jnp.ndarray,
                           ln2: jnp.ndarray, cfg: ModelConfig):
    """Per-query-head sparse attention (Quest baseline: no shared sparsity
    within the GQA group, §4.1).

    q_rope: [B, H, dh]; k_sel/v_sel: [B, H, T, dh] (gathered per query
    head); sel_mask: [B, H, T]. Returns x' [B, d].
    """
    b, h, dh = q_rope.shape
    logits = jnp.einsum("bhd,bhtd->bht", q_rope, k_sel) / jnp.sqrt(
        jnp.float32(dh))
    ok = sel_mask > 0
    logits = jnp.where(ok, logits, NEG_INF)
    m = logits.max(-1, keepdims=True)
    m = jnp.where(m > NEG_INF / 2, m, 0.0)
    e = jnp.where(ok, jnp.exp(logits - m), 0.0)
    probs = e / jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    attn = jnp.einsum("bht,bhtd->bhd", probs, v_sel).reshape(b, h * dh)
    x = resid + attn @ wo
    return x + mlp(rmsnorm(x, ln2, cfg.rms_eps), w1, w2)


def layer_post_dense(q_rope: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, seq_len: jnp.ndarray,
                     resid: jnp.ndarray, wo: jnp.ndarray, w1: jnp.ndarray,
                     w2: jnp.ndarray, ln2: jnp.ndarray, cfg: ModelConfig):
    """Dense decode attention over the full KV cache (baseline).

    k_cache/v_cache: [B, Hkv, S, dh]; seq_len: [B] int32.
    """
    b, h, dh = q_rope.shape
    s = k_cache.shape[2]
    mask = (jnp.arange(s)[None, None] <
            seq_len[:, None, None]).astype(jnp.float32)  # [B,1,S]
    mask = jnp.broadcast_to(mask, (b, cfg.n_kv_heads, s))
    return layer_post_sel(q_rope, k_cache, v_cache, mask, resid, wo, w1,
                          w2, ln2, cfg)


def lm_head(x: jnp.ndarray, ln_f: jnp.ndarray, head: jnp.ndarray,
            cfg: ModelConfig) -> jnp.ndarray:
    """Final norm + output projection. x: [B, d] -> logits [B, V]."""
    return rmsnorm(x, ln_f, cfg.rms_eps) @ head
