"""Rotary positional embedding (RoPE, Su et al. 2024) helpers.

Used in three places, mirroring the paper:
  * the base model's attention Q/K (standard RoPE over head_dim),
  * the AttnGate query path (RoPE over d_gate at the query's absolute
    position, eq. 1a),
  * the AttnGate key-compression path (RoPE over d_gate with the position
    of the *first token of each block*, eq. 1b / §2.2).
"""

from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for a rotary embedding of width ``dim``."""
    assert dim % 2 == 0, "RoPE width must be even"
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def rope_cos_sin(positions: jnp.ndarray, dim: int, theta: float):
    """cos/sin tables for integer ``positions`` (any shape).

    Returns arrays of shape positions.shape + (dim//2,).
    """
    freqs = rope_freqs(dim, theta)  # [dim/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., dim/2]
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Apply RoPE to the trailing dimension of ``x``.

    ``x``: [..., dim]; ``positions``: broadcastable to x.shape[:-1].
    Uses the interleaved-pair convention: (x_even, x_odd) rotated per pair.
    """
    dim = x.shape[-1]
    cos, sin = rope_cos_sin(positions, dim, theta)  # [..., dim/2]
    x_even = x[..., 0::2]
    x_odd = x[..., 1::2]
    out_even = x_even * cos - x_odd * sin
    out_odd = x_even * sin + x_odd * cos
    # Re-interleave.
    out = jnp.stack([out_even, out_odd], axis=-1)
    return out.reshape(x.shape)
