"""AttnGate for sparse decoding (paper §2.2, eq. 1a-1c).

  Q path:  pre-RoPE query heads, concatenated per GQA group, projected by a
           per-KV-head linear W_q_gate [g*dh, dg], then RoPE at the query's
           absolute position -> one gate query per KV head (shared
           sparsity inside the group).
  K path:  pre-RoPE keys, non-overlapping per-block {max,min,avg} pooling
           along the sequence, concatenated (3*dh) and projected by
           W_k_gate [3*dh, dg], then RoPE with the position of the block's
           first token. The result is the "K compression cache" entry.
  Score:   q_gate · KC^T / sqrt(dg); budget mode consumes raw logits
           (top-k is softmax-invariant, §3.1), threshold mode applies a
           softmax over complete blocks first.
"""

from __future__ import annotations

import jax.numpy as jnp

from .rope import apply_rope

NEG_INF = -1e30


def gate_query(wq_gate: jnp.ndarray, q_prerope: jnp.ndarray,
               positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Aggregate a GQA group of pre-RoPE queries into one gate query.

    wq_gate: [Hkv, g*dh, dg]; q_prerope: [..., H, dh] (H = Hkv*g, heads of
    one group contiguous); positions: broadcastable to q_prerope.shape[:-2].
    Returns q_gate [..., Hkv, dg] (RoPE-applied).
    """
    hkv, gdh, dg = wq_gate.shape
    lead = q_prerope.shape[:-2]
    h, dh = q_prerope.shape[-2:]
    g = gdh // dh
    assert h == hkv * g
    qg = q_prerope.reshape(*lead, hkv, g * dh)
    qg = jnp.einsum("...kd,kde->...ke", qg, wq_gate)  # [..., Hkv, dg]
    return apply_rope(qg, positions[..., None], theta)


def pool_k_block(k_block: jnp.ndarray) -> jnp.ndarray:
    """{max,min,avg}-pool one block of pre-RoPE keys along the sequence.

    k_block: [..., block, dh] -> [..., 3*dh] (max ++ min ++ avg).
    """
    return jnp.concatenate(
        [k_block.max(-2), k_block.min(-2), k_block.mean(-2)], axis=-1)


def k_compress(wk_gate: jnp.ndarray, k_prerope: jnp.ndarray,
               block_size: int, theta: float) -> jnp.ndarray:
    """Build the full K compression cache for a sequence of keys.

    wk_gate: [Hkv, 3*dh, dg]; k_prerope: [B, Hkv, S, dh] (S divisible by
    block_size). Returns KC [B, Hkv, NBLK, dg], RoPE'd at block starts.
    """
    b, hkv, s, dh = k_prerope.shape
    nblk = s // block_size
    blocks = k_prerope.reshape(b, hkv, nblk, block_size, dh)
    pooled = pool_k_block(blocks)  # [B, Hkv, NBLK, 3*dh]
    kc = jnp.einsum("bknd,kde->bkne", pooled, wk_gate)  # [B, Hkv, NBLK, dg]
    starts = jnp.arange(nblk, dtype=jnp.int32) * block_size
    return apply_rope(kc, starts[None, None, :], theta)


def gate_scores(q_gate: jnp.ndarray, kc: jnp.ndarray) -> jnp.ndarray:
    """Raw gate logits. q_gate: [..., Hkv, dg]; kc: [B, Hkv, NBLK, dg].
    Returns [..., Hkv, NBLK] (q leading dims must start with B)."""
    dg = q_gate.shape[-1]
    return jnp.einsum("b...ke,bkne->b...kn", q_gate, kc) / jnp.sqrt(
        jnp.float32(dg))


def gate_log_softmax(scores: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Masked log-softmax over the block dimension (last axis)."""
    masked = jnp.where(valid, scores, NEG_INF)
    m = masked.max(-1, keepdims=True)
    m = jnp.where(m > NEG_INF / 2, m, 0.0)
    e = jnp.where(valid, jnp.exp(masked - m), 0.0)
    denom = jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    return jnp.where(valid, masked - m - jnp.log(denom), 0.0)


def distill_kl(gate_logits: jnp.ndarray, gt_norm: jnp.ndarray,
               block_size: int) -> jnp.ndarray:
    """KL(gt || gate) averaged over positions with >=1 complete block.

    gate_logits: [B, S, Hkv, NBLK]; gt_norm: [B, Hkv, S, NBLK] already
    masked+normalised (ref.normalize_gt). Valid blocks: j < t // block.
    """
    b, s, hkv, nblk = gate_logits.shape
    t = jnp.arange(s)[:, None]
    j = jnp.arange(nblk)[None, :]
    valid = (j < t // block_size)  # [S, NBLK]
    logp = gate_log_softmax(gate_logits,
                            valid[None, :, None, :])  # [B, S, Hkv, NBLK]
    gt = jnp.transpose(gt_norm, (0, 2, 1, 3))  # [B, S, Hkv, NBLK]
    # Rows whose GT sums to zero (t < block) contribute nothing.
    row_ok = gt.sum(-1) > 0  # [B, S, Hkv]
    log_gt = jnp.where(gt > 0, jnp.log(jnp.maximum(gt, 1e-30)), 0.0)
    kl_row = (gt * (log_gt - logp)).sum(-1)  # [B, S, Hkv]
    n = jnp.maximum(row_ok.sum(), 1)
    return jnp.where(row_ok, kl_row, 0.0).sum() / n
