"""AttnGate unit tests: query aggregation, K compression, RoPE, KL loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import gate as G
from compile.config import DEFAULT_MODEL as cfg
from compile.kernels import ref
from compile.rope import apply_rope

TOL = dict(rtol=1e-5, atol=1e-5)


class TestRope:
    def test_preserves_norm(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
        pos = jnp.array([0, 5, 100, 511])
        y = apply_rope(x, pos, 10000.0)
        np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                                   jnp.linalg.norm(x, axis=-1), **TOL)

    def test_position_zero_is_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (3, 32))
        y = apply_rope(x, jnp.zeros(3, dtype=jnp.int32), 10000.0)
        np.testing.assert_allclose(y, x, **TOL)

    def test_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n."""
        q = jax.random.normal(jax.random.PRNGKey(2), (16,))
        k = jax.random.normal(jax.random.PRNGKey(3), (16,))
        def dot(m, n):
            qm = apply_rope(q[None], jnp.array([m]), 10000.0)[0]
            kn = apply_rope(k[None], jnp.array([n]), 10000.0)[0]
            return float(qm @ kn)
        assert abs(dot(7, 3) - dot(104, 100)) < 1e-4
        assert abs(dot(0, 0) - dot(50, 50)) < 1e-4


class TestPooling:
    def test_pool_components(self):
        k = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 16))
        p = G.pool_k_block(k)
        assert p.shape == (2, 3 * 16)
        np.testing.assert_allclose(p[:, :16], k.max(-2), **TOL)
        np.testing.assert_allclose(p[:, 16:32], k.min(-2), **TOL)
        np.testing.assert_allclose(p[:, 32:], k.mean(-2), **TOL)

    def test_pool_constant_block(self):
        """max == min == avg for a constant block."""
        k = jnp.ones((1, 4, 8)) * 3.5
        p = G.pool_k_block(k)
        np.testing.assert_allclose(p, 3.5, **TOL)


class TestKCompress:
    def test_shape_and_block_independence(self):
        hkv, dh, dg, bs = 2, 16, 8, 4
        wk = jax.random.normal(jax.random.PRNGKey(5), (hkv, 3 * dh, dg))
        k = jax.random.normal(jax.random.PRNGKey(6), (1, hkv, 3 * bs, dh))
        kc = G.k_compress(wk, k, bs, 10000.0)
        assert kc.shape == (1, hkv, 3, dg)
        # Changing block 2's keys must not change blocks 0-1 entries.
        k2 = k.at[:, :, 2 * bs:].set(0.0)
        kc2 = G.k_compress(wk, k2, bs, 10000.0)
        np.testing.assert_allclose(kc[:, :, :2], kc2[:, :, :2], **TOL)

    def test_rope_positions_are_block_starts(self):
        """A single repeated key block should only differ between block
        entries by the RoPE rotation at the block-start positions."""
        hkv, dh, dg, bs = 1, 8, 8, 4
        wk = jax.random.normal(jax.random.PRNGKey(7), (hkv, 3 * dh, dg))
        blk = jax.random.normal(jax.random.PRNGKey(8), (1, hkv, bs, dh))
        k = jnp.concatenate([blk, blk], axis=2)
        kc = G.k_compress(wk, k, bs, 10000.0)
        pooled = G.pool_k_block(blk.reshape(1, hkv, 1, bs, dh))
        raw = jnp.einsum("bknd,kde->bkne", pooled, wk)
        exp0 = apply_rope(raw, jnp.array([0])[None, None, :], 10000.0)
        exp1 = apply_rope(raw, jnp.array([bs])[None, None, :], 10000.0)
        np.testing.assert_allclose(kc[:, :, 0], exp0[:, :, 0], **TOL)
        np.testing.assert_allclose(kc[:, :, 1], exp1[:, :, 0], **TOL)


class TestGateQuery:
    def test_group_aggregation_shape(self):
        hkv, g, dh, dg = 2, 4, 16, 8
        wq = jax.random.normal(jax.random.PRNGKey(9), (hkv, g * dh, dg))
        q = jax.random.normal(jax.random.PRNGKey(10), (3, hkv * g, dh))
        pos = jnp.array([1, 2, 3], dtype=jnp.int32)
        qg = G.gate_query(wq, q, pos, 10000.0)
        assert qg.shape == (3, hkv, dg)

    def test_group_heads_feed_their_kv_head(self):
        """Zeroing the queries of group 1 changes only gate head 1."""
        hkv, g, dh, dg = 2, 2, 8, 8
        wq = jax.random.normal(jax.random.PRNGKey(11), (hkv, g * dh, dg))
        q = jax.random.normal(jax.random.PRNGKey(12), (1, hkv * g, dh))
        pos = jnp.zeros(1, dtype=jnp.int32)
        qg = G.gate_query(wq, q, pos, 10000.0)
        q2 = q.at[:, g:].set(0.0)  # zero group 1 (heads g..2g-1)
        qg2 = G.gate_query(wq, q2, pos, 10000.0)
        np.testing.assert_allclose(qg[:, 0], qg2[:, 0], **TOL)
        assert not np.allclose(qg[:, 1], qg2[:, 1])

    def test_sequence_batched(self):
        hkv, g, dh, dg = 2, 4, 16, 8
        wq = jax.random.normal(jax.random.PRNGKey(13), (hkv, g * dh, dg))
        q = jax.random.normal(jax.random.PRNGKey(14), (2, 5, hkv * g, dh))
        pos = jnp.broadcast_to(jnp.arange(5, dtype=jnp.int32), (2, 5))
        qg = G.gate_query(wq, q, pos, 10000.0)
        assert qg.shape == (2, 5, hkv, dg)
        # Row 3 equals the single-token call at position 3.
        qg3 = G.gate_query(wq, q[:, 3], pos[:, 3], 10000.0)
        np.testing.assert_allclose(qg[:, 3], qg3, **TOL)


class TestDistillKL:
    def _mk(self, seed, b=1, s=64, hkv=2, bs=16):
        nblk = s // bs
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        logits = jax.random.normal(k1, (b, s, hkv, nblk))
        raw = jax.nn.softmax(jax.random.normal(k2, (b, hkv, s, nblk)))
        gt = ref.normalize_gt(raw, bs)
        return logits, gt, bs

    def test_kl_nonnegative(self):
        logits, gt, bs = self._mk(0)
        assert float(G.distill_kl(logits, gt, bs)) >= -1e-6

    def test_kl_zero_when_gate_matches_gt(self):
        _, gt, bs = self._mk(1)
        # Use log(gt) as logits -> masked softmax reproduces gt exactly.
        safe = jnp.log(jnp.maximum(jnp.transpose(gt, (0, 2, 1, 3)), 1e-30))
        kl = float(G.distill_kl(safe, gt, bs))
        assert abs(kl) < 1e-4

    def test_kl_decreases_toward_gt(self):
        logits, gt, bs = self._mk(2)
        kl0 = float(G.distill_kl(logits, gt, bs))
        tgt = jnp.log(jnp.maximum(jnp.transpose(gt, (0, 2, 1, 3)), 1e-30))
        kl_half = float(G.distill_kl(0.5 * logits + 0.5 * tgt, gt, bs))
        assert kl_half < kl0

    @settings(deadline=None, max_examples=6)
    @given(st.integers(0, 100))
    def test_gradient_only_on_valid_blocks(self, seed):
        logits, gt, bs = self._mk(seed)
        grad = jax.grad(lambda lg: G.distill_kl(lg, gt, bs))(logits)
        s, nblk = logits.shape[1], logits.shape[3]
        t = np.arange(s)[:, None]
        j = np.arange(nblk)[None, :]
        invalid = ~(j < t // bs)
        gm = np.asarray(jnp.transpose(grad, (0, 1, 3, 2)))  # [B,S,NBLK,Hkv]
        assert np.abs(gm[:, invalid]).max() < 1e-8
