"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/GQA-group settings and asserts allclose against
ref.py — the core correctness signal for the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.block_sparse_decode import block_sparse_decode, dense_decode
from compile.kernels.gt_flash import gt_flash

TOL = dict(rtol=2e-5, atol=2e-5)


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             dtype=jnp.float32)


# ---------------------------------------------------------------------------
# gt_flash: flash forward + ground-truth block scores (paper Fig 2b)
# ---------------------------------------------------------------------------

class TestGtFlash:
    @pytest.mark.parametrize("g", [1, 2, 4])
    @pytest.mark.parametrize("bs", [8, 16, 32])
    def test_matches_ref(self, g, bs):
        B, Hkv, S, D = 2, 2, 128, 16
        H = Hkv * g
        q, k, v = rand(0, (B, H, S, D)), rand(1, (B, Hkv, S, D)), rand(
            2, (B, Hkv, S, D))
        out, gt = gt_flash(q, k, v, group=g, block_k=bs, block_q=32)
        out_ref, probs = ref.causal_attention_ref(q, k, v, g)
        np.testing.assert_allclose(out, out_ref, **TOL)
        gt_ref = probs.reshape(B, H, S, S // bs, bs).max(-1)
        np.testing.assert_allclose(gt, gt_ref, **TOL)

    def test_gt_rows_bounded_by_one(self):
        B, H, S, D = 1, 2, 64, 8
        q, k, v = rand(3, (B, H, S, D)), rand(4, (B, 2, S, D)), rand(
            5, (B, 2, S, D))
        _, gt = gt_flash(q, k, v, group=1, block_k=16, block_q=16)
        assert float(gt.max()) <= 1.0 + 1e-5
        assert float(gt.min()) >= 0.0

    def test_first_row_attends_only_block0(self):
        """Query 0 can only attend to token 0 -> gt[..,0,0] == 1, rest 0."""
        B, H, S, D = 1, 2, 64, 8
        q, k, v = rand(6, (B, H, S, D)), rand(7, (B, 2, S, D)), rand(
            8, (B, 2, S, D))
        _, gt = gt_flash(q, k, v, group=1, block_k=16, block_q=16)
        np.testing.assert_allclose(gt[:, :, 0, 0], 1.0, **TOL)
        np.testing.assert_allclose(gt[:, :, 0, 1:], 0.0, **TOL)

    @settings(deadline=None, max_examples=8)
    @given(st.integers(1, 3), st.sampled_from([16, 32]),
           st.sampled_from([8, 16]), st.integers(0, 100))
    def test_hypothesis_sweep(self, hkv, block_q, block_k, seed):
        g = 2
        B, S, D = 1, 64, 8
        H = hkv * g
        q = jax.random.normal(jax.random.PRNGKey(seed), (B, H, S, D))
        k = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, hkv, S, D))
        v = jax.random.normal(jax.random.PRNGKey(seed + 2), (B, hkv, S, D))
        out, gt = gt_flash(q, k, v, group=g, block_k=block_k,
                           block_q=block_q)
        out_ref, probs = ref.causal_attention_ref(q, k, v, g)
        np.testing.assert_allclose(out, out_ref, **TOL)
        gt_ref = probs.reshape(B, H, S, S // block_k, block_k).max(-1)
        np.testing.assert_allclose(gt, gt_ref, **TOL)


# ---------------------------------------------------------------------------
# block-sparse flash decode (paper §3.3)
# ---------------------------------------------------------------------------

class TestSparseDecode:
    def test_full_selection_equals_dense(self):
        B, H, Hkv, S, D, bs = 2, 8, 2, 256, 32, 16
        q = rand(10, (B, H, D))
        k, v = rand(11, (B, Hkv, S, D)), rand(12, (B, Hkv, S, D))
        sl = jnp.array([256, 200], dtype=jnp.int32)
        nblk = S // bs
        idx = jnp.broadcast_to(jnp.arange(nblk, dtype=jnp.int32),
                               (B, Hkv, nblk))
        o_sp = block_sparse_decode(q, k, v, idx, sl, block_size=bs)
        o_d = dense_decode(q, k, v, sl, block_size=bs)
        np.testing.assert_allclose(o_sp, o_d, **TOL)
        np.testing.assert_allclose(o_d, ref.dense_decode_ref(q, k, v, sl),
                                   **TOL)

    def test_padding_indices_ignored(self):
        B, H, Hkv, S, D, bs = 1, 4, 2, 128, 16, 16
        q = rand(13, (B, H, D))
        k, v = rand(14, (B, Hkv, S, D)), rand(15, (B, Hkv, S, D))
        sl = jnp.array([128], dtype=jnp.int32)
        idx_a = jnp.array([[[0, 3, -1, -1], [2, 5, -1, -1]]], jnp.int32)
        idx_b = jnp.array([[[0, 3, -1, -1, -1, -1],
                            [2, 5, -1, -1, -1, -1]]], jnp.int32)
        o_a = block_sparse_decode(q, k, v, idx_a, sl, block_size=bs)
        o_b = block_sparse_decode(q, k, v, idx_b, sl, block_size=bs)
        np.testing.assert_allclose(o_a, o_b, **TOL)

    def test_partial_last_block_masked_by_len(self):
        """Selected last block beyond seq_len contributes nothing."""
        B, H, Hkv, S, D, bs = 1, 4, 2, 64, 16, 16
        q = rand(16, (B, H, D))
        k, v = rand(17, (B, Hkv, S, D)), rand(18, (B, Hkv, S, D))
        sl = jnp.array([40], dtype=jnp.int32)  # block 2 is partial (32..39)
        idx = jnp.array([[[0, 1, 2, -1], [0, 1, 2, -1]]], jnp.int32)
        o = block_sparse_decode(q, k, v, idx, sl, block_size=bs)
        np.testing.assert_allclose(
            o, ref.sparse_decode_ref(q, k, v, idx, sl, bs), **TOL)

    def test_unsorted_and_duplicate_free_order_invariance(self):
        B, H, Hkv, S, D, bs = 1, 4, 2, 128, 16, 16
        q = rand(19, (B, H, D))
        k, v = rand(20, (B, Hkv, S, D)), rand(21, (B, Hkv, S, D))
        sl = jnp.array([128], dtype=jnp.int32)
        idx1 = jnp.array([[[0, 2, 5, 7], [1, 3, 4, 6]]], jnp.int32)
        idx2 = jnp.array([[[7, 5, 2, 0], [6, 4, 3, 1]]], jnp.int32)
        o1 = block_sparse_decode(q, k, v, idx1, sl, block_size=bs)
        o2 = block_sparse_decode(q, k, v, idx2, sl, block_size=bs)
        np.testing.assert_allclose(o1, o2, **TOL)

    @settings(deadline=None, max_examples=10)
    @given(st.integers(1, 2), st.integers(1, 4), st.integers(8, 128),
           st.integers(0, 1000))
    def test_hypothesis_sweep(self, hkv, g, seq_len, seed):
        bs, D, B = 16, 8, 1
        S = 128
        H = hkv * g
        kq, kk, kv, ki = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = jax.random.normal(kq, (B, H, D))
        k = jax.random.normal(kk, (B, hkv, S, D))
        v = jax.random.normal(kv, (B, hkv, S, D))
        nblk = S // bs
        # Random subset of blocks per kv head, padded with -1.
        sel = jax.random.bernoulli(ki, 0.5, (B, hkv, nblk))
        idx = jnp.where(sel, jnp.arange(nblk, dtype=jnp.int32), -1)
        # Always keep block 0 so the softmax is never empty.
        idx = idx.at[:, :, 0].set(0)
        sl = jnp.array([max(seq_len, 1)], dtype=jnp.int32)
        o = block_sparse_decode(q, k, v, idx, sl, block_size=bs)
        o_ref = ref.sparse_decode_ref(q, k, v, idx, sl, bs)
        np.testing.assert_allclose(o, o_ref, **TOL)


class TestDenseDecode:
    @settings(deadline=None, max_examples=8)
    @given(st.integers(1, 128), st.integers(0, 50))
    def test_hypothesis_matches_ref(self, seq_len, seed):
        B, H, Hkv, S, D, bs = 2, 4, 2, 128, 16, 16
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(kq, (B, H, D))
        k = jax.random.normal(kk, (B, Hkv, S, D))
        v = jax.random.normal(kv, (B, Hkv, S, D))
        sl = jnp.array([seq_len, S], dtype=jnp.int32)
        o = dense_decode(q, k, v, sl, block_size=bs)
        np.testing.assert_allclose(o, ref.dense_decode_ref(q, k, v, sl),
                                   **TOL)


class TestSparsePrefill:
    """block_sparse_prefill (the §6.3 unification kernel) vs oracle."""

    def _mk(self, seed, B=1, Hkv=2, g=2, S=64, D=8):
        H = Hkv * g
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(kq, (B, H, S, D))
        k = jax.random.normal(kk, (B, Hkv, S, D))
        v = jax.random.normal(kv, (B, Hkv, S, D))
        return q, k, v

    def test_full_mask_equals_dense(self):
        from compile.kernels.block_sparse_prefill import block_sparse_prefill
        q, k, v = self._mk(0)
        bq = bk = 16
        mask = jnp.ones((1, 2, 4, 4))
        out = block_sparse_prefill(q, k, v, mask, group=2, block_q=bq,
                                   block_k=bk)
        ref_out, _ = ref.causal_attention_ref(q, k, v, 2)
        np.testing.assert_allclose(out, ref_out, **TOL)

    def test_diagonal_plus_random_mask(self):
        from compile.kernels.block_sparse_prefill import block_sparse_prefill
        q, k, v = self._mk(1)
        bq = bk = 16
        key = jax.random.PRNGKey(2)
        mask = jax.random.bernoulli(key, 0.5, (1, 2, 4, 4)).astype(
            jnp.float32)
        # Diagonal always active (engine invariant).
        mask = jnp.maximum(mask, jnp.eye(4)[None, None])
        out = block_sparse_prefill(q, k, v, mask, group=2, block_q=bq,
                                   block_k=bk)
        expect = ref.sparse_prefill_ref(q, k, v, mask, bq, bk)
        np.testing.assert_allclose(out, expect, **TOL)

    @settings(deadline=None, max_examples=6)
    @given(st.integers(0, 500), st.sampled_from([8, 16]))
    def test_hypothesis_sweep(self, seed, bk):
        from compile.kernels.block_sparse_prefill import block_sparse_prefill
        q, k, v = self._mk(seed)
        bq = 16
        nqb, nkb = 64 // bq, 64 // bk
        key = jax.random.PRNGKey(seed + 7)
        mask = jax.random.bernoulli(key, 0.6, (1, 2, nqb, nkb)).astype(
            jnp.float32)
        # Keep every row non-empty: activate key-block 0.
        mask = mask.at[:, :, :, 0].set(1.0)
        out = block_sparse_prefill(q, k, v, mask, group=2, block_q=bq,
                                   block_k=bk)
        expect = ref.sparse_prefill_ref(q, k, v, mask, bq, bk)
        np.testing.assert_allclose(out, expect, **TOL)
