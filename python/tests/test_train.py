"""Training-step tests: AdamW math, overfitting a batch, distillation KL."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import params as P
from compile import train as T
from compile.config import ModelConfig

tcfg = ModelConfig(vocab=64, d_model=64, n_layers=2, n_heads=4,
                   n_kv_heads=2, head_dim=16, mlp_hidden=128, block_size=8,
                   max_seq=64)


class TestAdamW:
    def test_first_step_matches_manual(self):
        p = [jnp.array([[1.0, 2.0], [3.0, 4.0]])]
        g = [jnp.array([[0.1, -0.2], [0.3, 0.0]])]
        m = [jnp.zeros((2, 2))]
        v = [jnp.zeros((2, 2))]
        lr = jnp.float32(0.01)
        new_p, new_m, new_v = T._adamw_update(p, g, m, v, jnp.float32(0), lr)
        # After bias correction, step 1 update = sign-ish g/(|g|+eps).
        m1 = (1 - T.ADAM_B1) * g[0] / (1 - T.ADAM_B1)
        v1 = (1 - T.ADAM_B2) * g[0] ** 2 / (1 - T.ADAM_B2)
        upd = m1 / (jnp.sqrt(v1) + T.ADAM_EPS)
        expect = p[0] - 0.01 * (upd + T.WEIGHT_DECAY * p[0])
        np.testing.assert_allclose(new_p[0], expect, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(new_m[0], T.ADAM_B1 * 0 +
                                   (1 - T.ADAM_B1) * g[0], rtol=1e-6)

    def test_no_weight_decay_on_vectors(self):
        p = [jnp.ones((4,))]
        g = [jnp.zeros((4,))]
        m = [jnp.zeros((4,))]
        v = [jnp.zeros((4,))]
        new_p, _, _ = T._adamw_update(p, g, m, v, jnp.float32(0),
                                      jnp.float32(0.1))
        np.testing.assert_allclose(new_p[0], p[0], atol=1e-7)


class TestPretrain:
    def test_loss_decreases_overfitting_one_batch(self):
        cfg = tcfg
        ps = P.init_params(cfg, seed=0)
        ms = [jnp.zeros_like(x) for x in ps]
        vs = [jnp.zeros_like(x) for x in ps]
        key = jax.random.PRNGKey(0)
        ids = jax.random.randint(key, (2, 64), 0, cfg.vocab)
        w = jnp.ones((2, 64))
        step_fn = jax.jit(lambda p, m, v, s, i, w: T.pretrain_step(
            p, m, v, s, jnp.float32(3e-3), i, w, cfg))
        losses = []
        for i in range(8):
            ps, ms, vs, loss = step_fn(ps, ms, vs, jnp.float32(i), ids, w)
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.8, losses

    def test_loss_mask_zeroes_contribution(self):
        cfg = tcfg
        ps = P.init_params(cfg, seed=1)
        key = jax.random.PRNGKey(1)
        ids = jax.random.randint(key, (2, 64), 0, cfg.vocab)
        w0 = jnp.ones((2, 64))
        l_full = float(T.lm_loss(ps, cfg, ids, w0))
        # Mask half: loss changes (different positions averaged).
        w1 = w0.at[:, 32:].set(0.0)
        l_half = float(T.lm_loss(ps, cfg, ids, w1))
        assert l_full != pytest.approx(l_half, rel=1e-3)
        # All-zero mask -> guarded denominator, loss 0.
        l_zero = float(T.lm_loss(ps, cfg, ids, jnp.zeros((2, 64))))
        assert l_zero == pytest.approx(0.0, abs=1e-6)


class TestDistill:
    def test_kl_decreases(self):
        cfg = tcfg
        ps = P.init_params(cfg, seed=2)
        gs = P.init_gate(cfg, seed=3)
        gms = [jnp.zeros_like(x) for x in gs]
        gvs = [jnp.zeros_like(x) for x in gs]
        key = jax.random.PRNGKey(2)
        ids = jax.random.randint(key, (2, 64), 0, cfg.vocab)
        step_fn = jax.jit(lambda g, gm, gv, s, i: T.distill_step(
            ps, g, gm, gv, s, jnp.float32(2e-3), i, cfg, 8))
        kls = []
        for i in range(8):
            gs, gms, gvs, kl = step_fn(gs, gms, gvs, jnp.float32(i), ids)
            kls.append(float(kl))
        assert kls[-1] < kls[0] * 0.9, kls

    def test_base_model_frozen(self):
        """distill_step must not return updated base params (API) and the
        KL gradient w.r.t. base params must be blocked by stop_gradient."""
        cfg = tcfg
        ps = P.init_params(cfg, seed=4)
        gs = P.init_gate(cfg, seed=5)
        ids = jnp.zeros((1, 64), dtype=jnp.int32)
        g = jax.grad(lambda p: T.distill_loss(gs, p, cfg, ids, 8))(ps)
        total = sum(float(jnp.abs(x).sum()) for x in g)
        assert total == pytest.approx(0.0, abs=1e-8)

    def test_gate_forward_shapes(self):
        cfg = tcfg
        gs = P.init_gate(cfg, seed=6)
        b, s = 2, 64
        pre_qs = [jnp.zeros((b, s, cfg.n_heads, cfg.head_dim))
                  for _ in range(cfg.n_layers)]
        pre_ks = [jnp.zeros((b, cfg.n_kv_heads, s, cfg.head_dim))
                  for _ in range(cfg.n_layers)]
        out = T.gate_forward(gs, cfg, pre_qs, pre_ks, 8)
        assert len(out) == cfg.n_layers
        assert out[0].shape == (b, s, cfg.n_kv_heads, s // 8)
