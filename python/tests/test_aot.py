"""AOT pipeline tests: manifest structure, HLO text emission, checkpoint
round-trip, fixture generation."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import params as P
from compile.config import AotConfig, KernelBenchConfig, ModelConfig

tcfg = ModelConfig(vocab=64, d_model=64, n_layers=2, n_heads=4,
                   n_kv_heads=2, head_dim=16, mlp_hidden=128, block_size=8,
                   max_seq=64)
taot = AotConfig(decode_batch=2, prefill_len=64, sel_token_variants=(16,),
                 train_batch=1, train_len=64, distill_block_sizes=(8,),
                 distill_batch=1, distill_len=64)
tkb = KernelBenchConfig(n_heads=4, n_kv_heads=2, head_dim=16, block_size=16,
                        seqlens=(64,), batches=(1,), sparsities=(0.5,))


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    # Lower only the cheap executables; record every signature.
    aot.build_all(out, tcfg, taot, tkb,
                  only={"layer_pre", "lm_head", "layer_post_sel_t16",
                        "kb_dense_s64_b1", "kb_sparse_s64_b1_k2"})
    return out


class TestManifest:
    def test_manifest_complete(self, built):
        with open(os.path.join(built, "manifest.json")) as f:
            man = json.load(f)
        assert man["model"]["d_model"] == 64
        assert man["model"]["group_size"] == 2
        exes = man["executables"]
        for name in ("layer_pre", "prefill", "pretrain_step",
                     "distill_step_bs8", "layer_post_dense", "lm_head"):
            assert name in exes, name
        # Signatures carry dtype + shape for every arg.
        for e in exes.values():
            for a in e["args"]:
                assert a["dtype"] in ("f32", "i32")
                assert isinstance(a["shape"], list)

    def test_pretrain_signature_ordering(self, built):
        with open(os.path.join(built, "manifest.json")) as f:
            man = json.load(f)
        args = [a["name"] for a in man["executables"]["pretrain_step"]["args"]]
        np_ = len(man["params"])
        assert args[0] == "param:emb"
        assert args[np_] == "m:emb"
        assert args[2 * np_] == "v:emb"
        assert args[-4:] == ["step", "lr", "ids", "loss_w"]
        outs = man["executables"]["pretrain_step"]["outs"]
        assert outs[-1] == "loss" and len(outs) == 3 * np_ + 1

    def test_kbench_points(self, built):
        with open(os.path.join(built, "manifest.json")) as f:
            man = json.load(f)
        pts = man["kbench_points"]
        assert len(pts) == 1
        assert pts[0]["sparsity"] == 0.5
        assert pts[0]["k_sel"] == 2  # 4 blocks * (1 - 0.5)

    def test_hlo_text_emitted_and_parsable_header(self, built):
        p = os.path.join(built, "layer_pre.hlo.txt")
        text = open(p).read()
        assert "HloModule" in text and len(text) > 200

    def test_init_checkpoints_roundtrip(self, built):
        ps = P.load_flat(os.path.join(built, "model_init.bin"),
                         P.param_specs(tcfg))
        expect = P.init_params(tcfg)
        for a, b in zip(ps, expect):
            np.testing.assert_allclose(a, b, atol=0)
        gs = P.load_flat(os.path.join(built, "gate_init.bin"),
                         P.gate_specs(tcfg))
        assert len(gs) == 2 * tcfg.n_layers


class TestFixtures:
    def test_fixture_values(self, built):
        with open(os.path.join(built, "fixtures.json")) as f:
            fx = json.load(f)
        cfg = tcfg
        assert fx["config"]["d_gate"] == cfg.d_gate
        kc = fx["kcomp"]
        assert len(kc["expected_kc"]) == cfg.n_kv_heads * 2 * cfg.d_gate
        assert len(kc["k_pre"]) == cfg.n_kv_heads * 2 * cfg.block_size * \
            cfg.head_dim
        gq = fx["gate_query"]
        assert len(gq["expected_qg"]) == cfg.n_kv_heads * cfg.d_gate
        orc = fx["oracle"]
        assert len(orc["expected_gt"]) == cfg.n_kv_heads * 4
        # GT values are probabilities.
        gt = np.array(orc["expected_gt"])
        assert (gt >= 0).all() and (gt <= 1 + 1e-5).all()
