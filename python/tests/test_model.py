"""L2 model tests — most importantly the decode-path parity: stepping
token-by-token through layer_pre / layer_post (the Rust coordinator's
call sequence) must reproduce the full-sequence forward exactly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import params as P
from compile.config import ModelConfig

TOL = dict(rtol=3e-4, atol=3e-4)

# A tiny config so tests are fast; same structure as the default.
tcfg = ModelConfig(vocab=64, d_model=64, n_layers=2, n_heads=4,
                   n_kv_heads=2, head_dim=16, mlp_hidden=128, block_size=8,
                   max_seq=64)


@pytest.fixture(scope="module")
def tparams():
    return P.init_params(tcfg, seed=3)


def decode_logits_stepwise(params, cfg, ids_row, upto):
    """Reference 'Rust driver' in python: prefill on [0..upto), then dense
    decode steps for the remaining tokens; returns logits of final step."""
    p = P.as_dict(cfg, params)
    b = 1
    s = cfg.max_seq
    ids = jnp.zeros((b, s), dtype=jnp.int32).at[0, :len(ids_row)].set(
        jnp.asarray(ids_row))
    seq_len = jnp.array([upto], dtype=jnp.int32)
    logits, kc, vc, _ = M.prefill(params, cfg, ids, seq_len)
    k_cache = [np.array(kc[l]) for l in range(cfg.n_layers)]
    v_cache = [np.array(vc[l]) for l in range(cfg.n_layers)]
    last = None
    for t in range(upto, len(ids_row)):
        x = p["emb"][ids[:, t]]
        pos = jnp.array([t], dtype=jnp.int32)
        for l in range(cfg.n_layers):
            q, k_new, v_new, _, _ = M.layer_pre(
                x, pos, p[f"l{l}.wq"], p[f"l{l}.wk"], p[f"l{l}.wv"],
                p[f"l{l}.ln1"],
                jnp.zeros((cfg.n_kv_heads,
                           cfg.group_size * cfg.head_dim, cfg.d_gate)),
                cfg)
            k_cache[l][:, :, t] = np.asarray(k_new)
            v_cache[l][:, :, t] = np.asarray(v_new)
            x = M.layer_post_dense(
                q, jnp.asarray(k_cache[l]), jnp.asarray(v_cache[l]),
                jnp.array([t + 1], dtype=jnp.int32), x,
                p[f"l{l}.wo"], p[f"l{l}.w1"], p[f"l{l}.w2"],
                p[f"l{l}.ln2"], cfg)
        last = M.lm_head(x, p["ln_f"], p["head"], cfg)
    return last


class TestForward:
    def test_shapes(self, tparams):
        ids = jnp.zeros((2, 32), dtype=jnp.int32)
        logits = M.forward_train(tparams, tcfg, ids)
        assert logits.shape == (2, 32, tcfg.vocab)

    def test_causality(self, tparams):
        """Changing a future token must not affect earlier logits."""
        key = jax.random.PRNGKey(0)
        ids = jax.random.randint(key, (1, 32), 0, tcfg.vocab)
        l1 = M.forward_train(tparams, tcfg, ids)
        ids2 = ids.at[0, 20].set((ids[0, 20] + 1) % tcfg.vocab)
        l2 = M.forward_train(tparams, tcfg, ids2)
        np.testing.assert_allclose(l1[:, :20], l2[:, :20], **TOL)
        assert not np.allclose(l1[:, 20:], l2[:, 20:], atol=1e-3)

    def test_prefill_matches_forward(self, tparams):
        key = jax.random.PRNGKey(1)
        ids = jax.random.randint(key, (2, tcfg.max_seq), 0, tcfg.vocab)
        seq_len = jnp.array([tcfg.max_seq, 40], dtype=jnp.int32)
        logits_f = M.forward_train(tparams, tcfg, ids)
        logits_p, _, _, _ = M.prefill(tparams, tcfg, ids, seq_len)
        # Row 0: full length, all positions must match.
        np.testing.assert_allclose(logits_p[0], logits_f[0], **TOL)
        # Row 1: valid positions only.
        np.testing.assert_allclose(logits_p[1, :40], logits_f[1, :40], **TOL)

    def test_forward_with_gt_matches_forward(self, tparams):
        """The GT-kernel forward is the same model: logits unchanged."""
        key = jax.random.PRNGKey(2)
        ids = jax.random.randint(key, (1, 64), 0, tcfg.vocab)
        # forward_with_gt does not return logits; instead check the GT
        # normalisation invariants per layer.
        _, _, gts = M.forward_with_gt(tparams, tcfg, ids, 8)
        for gt in gts:
            sums = np.asarray(gt.sum(-1))
            t = np.arange(64)
            has_blocks = (t // 8) >= 1
            np.testing.assert_allclose(sums[:, :, has_blocks], 1.0,
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(sums[:, :, ~has_blocks], 0.0,
                                       atol=1e-6)


class TestDecodeParity:
    def test_stepwise_decode_matches_full_forward(self, tparams):
        """prefill + per-layer decode steps == forward_train (the exact
        call sequence the Rust engine performs)."""
        key = jax.random.PRNGKey(5)
        n = 24
        ids_row = list(np.asarray(
            jax.random.randint(key, (n,), 0, tcfg.vocab)))
        upto = 16
        last = decode_logits_stepwise(tparams, tcfg, ids_row, upto)
        ids_full = jnp.zeros((1, tcfg.max_seq), dtype=jnp.int32
                             ).at[0, :n].set(jnp.asarray(ids_row))
        logits_f = M.forward_train(tparams, tcfg, ids_full)
        np.testing.assert_allclose(last[0], logits_f[0, n - 1], **TOL)

    def test_sel_full_budget_matches_dense(self, tparams):
        """layer_post_sel with every block selected == layer_post_dense."""
        p = P.as_dict(tcfg, tparams)
        cfg = tcfg
        b, s = 2, cfg.max_seq
        key = jax.random.PRNGKey(6)
        q = jax.random.normal(key, (b, cfg.n_heads, cfg.head_dim))
        kc = jax.random.normal(jax.random.PRNGKey(7),
                               (b, cfg.n_kv_heads, s, cfg.head_dim))
        vc = jax.random.normal(jax.random.PRNGKey(8),
                               (b, cfg.n_kv_heads, s, cfg.head_dim))
        resid = jax.random.normal(jax.random.PRNGKey(9), (b, cfg.d_model))
        seq_len = jnp.array([s, 50], dtype=jnp.int32)
        args = (p["l0.wo"], p["l0.w1"], p["l0.w2"], p["l0.ln2"], cfg)
        dense = M.layer_post_dense(q, kc, vc, seq_len, resid, *args)
        mask = (jnp.arange(s)[None, None] < seq_len[:, None, None])
        mask = jnp.broadcast_to(mask, (b, cfg.n_kv_heads, s)).astype(
            jnp.float32)
        sel = M.layer_post_sel(q, kc, vc, mask, resid, *args)
        np.testing.assert_allclose(sel, dense, **TOL)

    def test_sel_gathered_subset(self, tparams):
        """Gathering blocks (as Rust does) + layer_post_sel == masked
        attention over the same token set."""
        p = P.as_dict(tcfg, tparams)
        cfg = tcfg
        bs = cfg.block_size
        b, s = 1, cfg.max_seq
        nblk = s // bs
        key = jax.random.PRNGKey(10)
        q = jax.random.normal(key, (b, cfg.n_heads, cfg.head_dim))
        kc = jax.random.normal(jax.random.PRNGKey(11),
                               (b, cfg.n_kv_heads, s, cfg.head_dim))
        vc = jax.random.normal(jax.random.PRNGKey(12),
                               (b, cfg.n_kv_heads, s, cfg.head_dim))
        resid = jax.random.normal(jax.random.PRNGKey(13), (b, cfg.d_model))
        args = (p["l0.wo"], p["l0.w1"], p["l0.w2"], p["l0.ln2"], cfg)
        # Select blocks {0, 3, 5} for head 0, {1, 3, 7} for head 1.
        sel_blocks = [[0, 3, 5], [1, 3, 7]]
        T = 3 * bs
        k_sel = np.zeros((b, cfg.n_kv_heads, T, cfg.head_dim), np.float32)
        v_sel = np.zeros_like(k_sel)
        for h, blocks in enumerate(sel_blocks):
            for i, j in enumerate(blocks):
                k_sel[0, h, i * bs:(i + 1) * bs] = np.asarray(
                    kc[0, h, j * bs:(j + 1) * bs])
                v_sel[0, h, i * bs:(i + 1) * bs] = np.asarray(
                    vc[0, h, j * bs:(j + 1) * bs])
        mask_sel = jnp.ones((b, cfg.n_kv_heads, T))
        out_g = M.layer_post_sel(q, jnp.asarray(k_sel), jnp.asarray(v_sel),
                                 mask_sel, resid, *args)
        # Equivalent token mask over the full cache.
        full_mask = np.zeros((b, cfg.n_kv_heads, s), np.float32)
        for h, blocks in enumerate(sel_blocks):
            for j in blocks:
                full_mask[0, h, j * bs:(j + 1) * bs] = 1.0
        out_m = M.layer_post_sel(q, kc, vc, jnp.asarray(full_mask), resid,
                                 *args)
        np.testing.assert_allclose(out_g, out_m, **TOL)


class TestPerHeadVariant:
    def test_perhead_equals_shared_when_selection_identical(self, tparams):
        """Per-query-head attention (Quest path) with every head of a
        group given the same gathered blocks == the shared-GQA variant."""
        p = P.as_dict(tcfg, tparams)
        cfg = tcfg
        b, T = 2, 32
        key = jax.random.PRNGKey(20)
        q = jax.random.normal(key, (b, cfg.n_heads, cfg.head_dim))
        k_sel = jax.random.normal(jax.random.PRNGKey(21),
                                  (b, cfg.n_kv_heads, T, cfg.head_dim))
        v_sel = jax.random.normal(jax.random.PRNGKey(22),
                                  (b, cfg.n_kv_heads, T, cfg.head_dim))
        mask = jnp.ones((b, cfg.n_kv_heads, T))
        resid = jax.random.normal(jax.random.PRNGKey(23), (b, cfg.d_model))
        args = (p["l0.wo"], p["l0.w1"], p["l0.w2"], p["l0.ln2"], cfg)
        shared = M.layer_post_sel(q, k_sel, v_sel, mask, resid, *args)
        kh = jnp.repeat(k_sel, cfg.group_size, axis=1)
        vh = jnp.repeat(v_sel, cfg.group_size, axis=1)
        mh = jnp.repeat(mask, cfg.group_size, axis=1)
        perhead = M.layer_post_sel_perhead(q, kh, vh, mh, resid, *args)
        np.testing.assert_allclose(perhead, shared, **TOL)
