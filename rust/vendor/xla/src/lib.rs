//! Offline API stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The container has no registry snapshot or PJRT plugin, so this crate
//! lets the `pjrt` feature *type-check and build* offline: it mirrors the
//! exact API surface `runtime::engine` uses and returns a descriptive
//! [`XlaError`] from every entry point at runtime. To actually execute
//! HLO, replace this directory with the real vendored `xla` crate — no
//! call sites change.

use std::borrow::Borrow;

#[derive(Debug, Clone)]
pub struct XlaError(pub String);

type XResult<T> = std::result::Result<T, XlaError>;

fn no_backend<T>(what: &str) -> XResult<T> {
    Err(XlaError(format!(
        "{what}: the vendored xla stub has no PJRT backend; vendor the real \
         xla-rs crate at rust/vendor/xla to run executables"
    )))
}

/// Element dtype of a literal. Marked non-exhaustive like the real
/// bindings, so downstream matches keep a wildcard arm.
#[non_exhaustive]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F32,
    F64,
}

/// Element types transferable to/from host buffers.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XResult<Literal> {
        no_backend("to_literal_sync")
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b<T: Borrow<PjRtBuffer>>(&self, _args: &[T])
                                            -> XResult<Vec<Vec<PjRtBuffer>>> {
        no_backend("execute_b")
    }
}

#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> XResult<PjRtClient> {
        no_backend("PjRtClient::cpu")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self, _data: &[T], _dims: &[usize], _device: Option<usize>,
    ) -> XResult<PjRtBuffer> {
        no_backend("buffer_from_host_buffer")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> XResult<PjRtLoadedExecutable> {
        no_backend("compile")
    }
}

#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> XResult<HloModuleProto> {
        no_backend("HloModuleProto::from_text_file")
    }
}

#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[derive(Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn array_shape(&self) -> XResult<ArrayShape> {
        no_backend("array_shape")
    }

    pub fn decompose_tuple(&mut self) -> XResult<Vec<Literal>> {
        no_backend("decompose_tuple")
    }

    pub fn to_vec<T: NativeType>(&self) -> XResult<Vec<T>> {
        no_backend("to_vec")
    }
}
