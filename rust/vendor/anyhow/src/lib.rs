//! Offline vendor stand-in for the `anyhow` crate.
//!
//! The build is fully offline (no registry), so this crate provides the
//! exact subset the workspace uses — `Error`, `Result`, `anyhow!`,
//! `bail!`, and `Context` — with the same semantics. Like the real
//! crate, `Error` deliberately does **not** implement `std::error::Error`
//! so the blanket `From<E: std::error::Error>` conversion (what makes
//! `?` work on io/parse errors) does not conflict with the identity
//! `From<Error>`. Swap this directory for the real vendored crate if a
//! registry snapshot ever becomes available; no call sites change.

use std::fmt;

/// Error: an owned message plus an optional boxed source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Construct from a concrete error value.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(src) = &self.source {
            let mut cur: Option<&(dyn std::error::Error + 'static)> = src.source();
            if cur.is_some() {
                write!(f, "\n\nCaused by:")?;
            }
            while let Some(e) = cur {
                write!(f, "\n    {e}")?;
                cur = e.source();
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach lazy context to an error, exactly like anyhow's trait.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt", args..)` — build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("fmt", args..)` — early-return `Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn bail_and_anyhow_format() {
        fn f(x: usize) -> Result<usize> {
            if x == 0 {
                bail!("zero not allowed (got {x})");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed (got 0)");
    }

    #[test]
    fn context_wraps_message() {
        let e = io_fail().with_context(|| "loading config").unwrap_err();
        assert!(e.to_string().starts_with("loading config: "), "{e}");
        let e = io_fail().context("plain").unwrap_err();
        assert!(e.to_string().starts_with("plain: "));
    }
}
