//! PJRT runtime: loads `artifacts/manifest.json`, compiles the HLO-text
//! executables on the CPU PJRT client (once per process), and provides a
//! typed call interface over host tensors / resident device buffers.
//!
//! Only `engine` talks to PJRT (the `xla` crate); it is gated behind the
//! `pjrt` feature so the pure-host layers (gate, sparse, kvcache, util,
//! workload, staging arena) build and test fully offline by default.

#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod tensor;

#[cfg(feature = "pjrt")]
pub use engine::{Arg, DeviceTensor, Runtime};
pub use manifest::{ArgSpec, ExeSpec, Manifest};
pub use tensor::{Data, HostTensor};
