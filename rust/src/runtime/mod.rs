//! PJRT runtime: loads `artifacts/manifest.json`, compiles the HLO-text
//! executables on the CPU PJRT client (once per process), and provides a
//! typed call interface over host tensors / resident device buffers.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::{Arg, DeviceTensor, Runtime};
pub use manifest::{ArgSpec, ExeSpec, Manifest};
pub use tensor::{Data, HostTensor};
