//! Manifest loading: the contract written by `python/compile/aot.py`.
//!
//! The manifest carries the model/gate configuration, the flat parameter
//! layout, and every executable's argument/output signature; the runtime
//! validates each call against it.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// One executable argument (name, dtype, static shape).
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// One AOT-lowered executable.
#[derive(Debug, Clone)]
pub struct ExeSpec {
    pub name: String,
    pub file: PathBuf,
    pub args: Vec<ArgSpec>,
    pub outs: Vec<String>,
}

/// A named tensor in the flat parameter layout.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One Fig 6 benchmark point (seqlen x batch x sparsity pair of exes).
#[derive(Debug, Clone)]
pub struct KbenchPoint {
    pub seqlen: usize,
    pub batch: usize,
    pub sparsity: f64,
    pub k_sel: usize,
    pub dense: String,
    pub sparse: String,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: Json,
    pub aot: Json,
    pub kbench: Json,
    pub kbench_points: Vec<KbenchPoint>,
    pub params: Vec<ParamSpec>,
    pub gate_params: Vec<ParamSpec>,
    pub executables: BTreeMap<String, ExeSpec>,
}

fn parse_param_list(j: &Json) -> Result<Vec<ParamSpec>> {
    j.as_arr()?
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: p.get("name")?.as_str()?.to_string(),
                shape: p.get("shape")?.as_usize_vec()?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        let mut executables = BTreeMap::new();
        for (name, e) in j.get("executables")?.as_obj()? {
            let args = e
                .get("args")?
                .as_arr()?
                .iter()
                .map(|a| {
                    Ok(ArgSpec {
                        name: a.get("name")?.as_str()?.to_string(),
                        dtype: a.get("dtype")?.as_str()?.to_string(),
                        shape: a.get("shape")?.as_usize_vec()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let outs = e
                .get("outs")?
                .as_arr()?
                .iter()
                .map(|o| Ok(o.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?;
            executables.insert(
                name.clone(),
                ExeSpec {
                    name: name.clone(),
                    file: dir.join(e.get("file")?.as_str()?),
                    args,
                    outs,
                },
            );
        }
        let kbench_points = j
            .get("kbench_points")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(KbenchPoint {
                    seqlen: p.get("seqlen")?.as_usize()?,
                    batch: p.get("batch")?.as_usize()?,
                    sparsity: p.get("sparsity")?.as_f64()?,
                    k_sel: p.get("k_sel")?.as_usize()?,
                    dense: p.get("dense")?.as_str()?.to_string(),
                    sparse: p.get("sparse")?.as_str()?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            model: j.get("model")?.clone(),
            aot: j.get("aot")?.clone(),
            kbench: j.get("kbench")?.clone(),
            kbench_points,
            params: parse_param_list(j.get("params")?)?,
            gate_params: parse_param_list(j.get("gate_params")?)?,
            executables,
        })
    }

    pub fn exe(&self, name: &str) -> Result<&ExeSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow!("unknown executable {name:?}"))
    }

    /// Smallest `layer_post_sel_t{T}` variant with T >= wanted tokens.
    pub fn sel_variant_for(&self, tokens: usize) -> Result<usize> {
        let variants = self.aot.get("sel_token_variants")?.as_usize_vec()?;
        variants
            .iter()
            .copied()
            .filter(|t| *t >= tokens)
            .min()
            .ok_or_else(|| anyhow!("no sel variant >= {tokens} (have {variants:?})"))
    }
}
