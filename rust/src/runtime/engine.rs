//! The PJRT execution engine: HLO text -> compiled executables -> typed
//! calls. Executables are compiled lazily and cached for the process
//! lifetime; weights can be uploaded once as resident device buffers and
//! mixed with per-call host tensors (the decode hot path does this).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ExeSpec, Manifest};
use super::tensor::{Data, HostTensor};

/// A tensor resident on the PJRT device (CPU plugin: pinned host memory).
pub struct DeviceTensor {
    pub buffer: xla::PjRtBuffer,
    pub shape: Vec<usize>,
    pub dtype: &'static str,
}

/// Call argument: borrowed host tensor (uploaded per call) or a resident
/// device buffer (uploaded once, e.g. model weights).
pub enum Arg<'a> {
    Host(&'a HostTensor),
    Dev(&'a DeviceTensor),
}

impl<'a> Arg<'a> {
    fn dtype(&self) -> &str {
        match self {
            Arg::Host(t) => t.dtype(),
            Arg::Dev(t) => t.dtype,
        }
    }

    fn shape(&self) -> &[usize] {
        match self {
            Arg::Host(t) => &t.shape,
            Arg::Dev(t) => &t.shape,
        }
    }
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    spec: ExeSpec,
}

/// Cumulative runtime counters (used by the perf harness).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub calls: u64,
    pub compile_s: f64,
    pub execute_s: f64,
    pub upload_bytes: u64,
    pub download_bytes: u64,
}

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Compiled>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Create a runtime over `artifacts/` (manifest + HLO text files).
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)
            .with_context(|| format!("loading manifest from {}", artifacts_dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    /// Upload a host tensor as a resident device buffer.
    pub fn upload(&self, t: &HostTensor) -> Result<DeviceTensor> {
        let buffer = match &t.data {
            Data::F32(v) => self
                .client
                .buffer_from_host_buffer::<f32>(v, &t.shape, None)
                .map_err(|e| anyhow!("upload f32: {e:?}"))?,
            Data::I32(v) => self
                .client
                .buffer_from_host_buffer::<i32>(v, &t.shape, None)
                .map_err(|e| anyhow!("upload i32: {e:?}"))?,
        };
        self.stats.borrow_mut().upload_bytes += 4 * t.numel() as u64;
        Ok(DeviceTensor { buffer, shape: t.shape.clone(), dtype: t.dtype() })
    }

    /// Ensure an executable is compiled; returns compile wall time if it
    /// happened now.
    pub fn prepare(&self, name: &str) -> Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.exe(name)?.clone();
        let t0 = Instant::now();
        let path = spec
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path"))?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse HLO text {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.stats.borrow_mut().compile_s += t0.elapsed().as_secs_f64();
        self.cache.borrow_mut().insert(name.to_string(), Compiled { exe, spec });
        Ok(())
    }

    /// Execute `name` with the given args; returns the decomposed output
    /// tuple as host tensors (order = manifest `outs`).
    pub fn call(&self, name: &str, args: &[Arg]) -> Result<Vec<HostTensor>> {
        self.prepare(name)?;
        let cache = self.cache.borrow();
        let compiled = cache.get(name).unwrap();
        let spec = &compiled.spec;
        if args.len() != spec.args.len() {
            bail!(
                "{name}: got {} args, expected {} ({:?})",
                args.len(),
                spec.args.len(),
                spec.args.iter().map(|a| a.name.as_str()).collect::<Vec<_>>()
            );
        }
        for (a, s) in args.iter().zip(&spec.args) {
            if a.dtype() != s.dtype || a.shape() != s.shape.as_slice() {
                bail!(
                    "{name}: arg {:?} has {}{:?}, expected {}{:?}",
                    s.name,
                    a.dtype(),
                    a.shape(),
                    s.dtype,
                    s.shape
                );
            }
        }
        // Stage: upload host args, borrow device args.
        let mut staged: Vec<DeviceTensor> = Vec::new();
        let mut order: Vec<usize> = Vec::new(); // index into staged or marker
        let mut upload = 0u64;
        for a in args {
            match a {
                Arg::Host(t) => {
                    staged.push(self.upload_quiet(t)?);
                    upload += 4 * t.numel() as u64;
                    order.push(staged.len()); // 1-based into staged
                }
                Arg::Dev(_) => order.push(0),
            }
        }
        let mut bufs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
        let mut si = 0usize;
        for (a, o) in args.iter().zip(&order) {
            match a {
                Arg::Host(_) => {
                    bufs.push(&staged[si].buffer);
                    si += 1;
                    debug_assert_eq!(*o, si);
                }
                Arg::Dev(d) => bufs.push(&d.buffer),
            }
        }
        let t0 = Instant::now();
        let result = compiled
            .exe
            .execute_b::<&xla::PjRtBuffer>(&bufs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let tuple = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("{name}: empty execution result"))?;
        let mut literal = tuple
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: to_literal: {e:?}"))?;
        let parts = literal
            .decompose_tuple()
            .map_err(|e| anyhow!("{name}: decompose: {e:?}"))?;
        let mut outs = Vec::with_capacity(parts.len());
        let mut download = 0u64;
        for part in parts {
            let t = literal_to_host(&part)?;
            download += 4 * t.numel() as u64;
            outs.push(t);
        }
        if outs.len() != spec.outs.len() {
            bail!("{name}: {} outputs, manifest says {}", outs.len(), spec.outs.len());
        }
        let mut st = self.stats.borrow_mut();
        st.calls += 1;
        st.execute_s += t0.elapsed().as_secs_f64();
        st.upload_bytes += upload;
        st.download_bytes += download;
        Ok(outs)
    }

    fn upload_quiet(&self, t: &HostTensor) -> Result<DeviceTensor> {
        let buffer = match &t.data {
            Data::F32(v) => self
                .client
                .buffer_from_host_buffer::<f32>(v, &t.shape, None)
                .map_err(|e| anyhow!("upload f32: {e:?}"))?,
            Data::I32(v) => self
                .client
                .buffer_from_host_buffer::<i32>(v, &t.shape, None)
                .map_err(|e| anyhow!("upload i32: {e:?}"))?,
        };
        Ok(DeviceTensor { buffer, shape: t.shape.clone(), dtype: t.dtype() })
    }
}

fn literal_to_host(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?;
            Ok(HostTensor::f32(dims, v))
        }
        xla::ElementType::S32 => {
            let v = lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))?;
            Ok(HostTensor::i32(dims, v))
        }
        other => bail!("unsupported output element type {other:?}"),
    }
}
