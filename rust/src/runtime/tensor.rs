//! Host-side tensors: the interchange type between the coordinator's own
//! math (gate scoring, gathers, sampling) and the PJRT executables.

use anyhow::{bail, Result};

/// Element storage. Everything in the model contract is f32 or i32.
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Dense row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} vs len {}", data.len());
        HostTensor { shape, data: Data::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} vs len {}", data.len());
        HostTensor { shape, data: Data::I32(data) }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor::f32(shape, vec![0.0; n])
    }

    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::f32(vec![], vec![x])
    }

    pub fn scalar_i32(x: i32) -> Self {
        HostTensor::i32(vec![], vec![x])
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dtype(&self) -> &'static str {
        match self.data {
            Data::F32(_) => "f32",
            Data::I32(_) => "i32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Strict shape check used at the runtime call boundary.
    pub fn check(&self, name: &str, dtype: &str, shape: &[usize]) -> Result<()> {
        if self.dtype() != dtype {
            bail!("arg {name}: dtype {} != expected {dtype}", self.dtype());
        }
        if self.shape != shape {
            bail!("arg {name}: shape {:?} != expected {shape:?}", self.shape);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = HostTensor::f32(vec![2, 3], vec![1.0; 6]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.dtype(), "f32");
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![0.0; 5]);
    }

    #[test]
    fn check_validates() {
        let t = HostTensor::i32(vec![4], vec![0; 4]);
        assert!(t.check("x", "i32", &[4]).is_ok());
        assert!(t.check("x", "f32", &[4]).is_err());
        assert!(t.check("x", "i32", &[2, 2]).is_err());
    }

    #[test]
    fn scalars_have_empty_shape() {
        assert_eq!(HostTensor::scalar_f32(1.5).shape, Vec::<usize>::new());
        assert_eq!(HostTensor::scalar_i32(3).numel(), 1);
    }
}
