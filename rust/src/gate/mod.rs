//! Host-side AttnGate math (paper §2.2 / §3.2).
//!
//! The gate *query* is produced by the `layer_pre` executable (it is part
//! of the model graph); everything downstream of it — the K compression
//! cache entries (pool + linear + RoPE), the block scores, and the
//! softmax for threshold mode — is tiny (a few thousand MACs per token)
//! and runs directly in the coordinator. This mirrors the paper's point
//! that AttnGate overhead is negligible, and keeps the selection decision
//! on the host where the paged KV metadata lives.
//!
//! Every function here is checked against the JAX reference through
//! `artifacts/fixtures.json` (see `rust/tests/parity.rs`).

use crate::model::ModelConfig;
use crate::util::simd;

/// Stack capacity for the per-call RoPE sin/cos pattern rows
/// (`RopeTable::apply`); dims above this take the reference per-row
/// path, which is bit-identical anyway.
const ROPE_PATTERN_CAP: usize = 512;

/// Apply interleaved-pair RoPE in place over the trailing dim of `x`.
/// Matches `python/compile/rope.py::apply_rope`.
///
/// Reference implementation: recomputes `theta^-(2i/dim)` in the inner
/// loop. Hot paths hold a [`RopeTable`] instead and call
/// [`RopeTable::apply`], which produces bit-identical rotations from the
/// cached frequencies.
pub fn rope_inplace(x: &mut [f32], dim: usize, pos: i64, theta: f64) {
    debug_assert_eq!(x.len() % dim, 0);
    debug_assert_eq!(dim % 2, 0);
    let half = dim / 2;
    for row in x.chunks_exact_mut(dim) {
        for i in 0..half {
            let freq = 1.0 / theta.powf(2.0 * i as f64 / dim as f64);
            let angle = pos as f64 * freq;
            let (sin, cos) = (angle.sin() as f32, angle.cos() as f32);
            let e = row[2 * i];
            let o = row[2 * i + 1];
            row[2 * i] = e * cos - o * sin;
            row[2 * i + 1] = e * sin + o * cos;
        }
    }
}

/// Precomputed RoPE frequency table for one `(dim, theta)` pair.
///
/// `theta.powf(...)` dominates the reference rotation's inner loop; the
/// table hoists it to construction time so per-position application costs
/// one `sin_cos` per frequency. Frequencies are computed with the exact
/// expression `rope_inplace` uses, so rotations are bit-identical.
#[derive(Debug, Clone)]
pub struct RopeTable {
    dim: usize,
    inv_freq: Vec<f64>,
}

impl RopeTable {
    pub fn new(dim: usize, theta: f64) -> RopeTable {
        assert_eq!(dim % 2, 0, "RoPE dim must be even");
        let inv_freq = (0..dim / 2)
            .map(|i| 1.0 / theta.powf(2.0 * i as f64 / dim as f64))
            .collect();
        RopeTable { dim, inv_freq }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// In-place interleaved-pair rotation at `pos` over every `dim`-long
    /// row of `x`. Bit-identical to `rope_inplace(x, dim, pos, theta)`.
    ///
    /// The `sin_cos` per frequency is hoisted out of the row loop into
    /// stack-resident interleaved patterns (`[c,c,..]`, `[-s,s,..]` —
    /// the exact f64 computation the reference performs, so the values
    /// are identical), then each row rotates through the runtime-
    /// dispatched [`simd::rope_rotate`] kernel, whose scalar fallback
    /// computes the same unfused mul/add expression as the reference.
    pub fn apply(&self, x: &mut [f32], pos: i64) {
        debug_assert_eq!(x.len() % self.dim, 0);
        if self.dim > ROPE_PATTERN_CAP {
            for row in x.chunks_exact_mut(self.dim) {
                self.apply_row_reference(row, pos);
            }
            return;
        }
        let mut cos2 = [0f32; ROPE_PATTERN_CAP];
        let mut nsin2 = [0f32; ROPE_PATTERN_CAP];
        for (i, f) in self.inv_freq.iter().enumerate() {
            let angle = pos as f64 * f;
            let (sin64, cos64) = angle.sin_cos();
            let (sin, cos) = (sin64 as f32, cos64 as f32);
            cos2[2 * i] = cos;
            cos2[2 * i + 1] = cos;
            nsin2[2 * i] = -sin;
            nsin2[2 * i + 1] = sin;
        }
        for row in x.chunks_exact_mut(self.dim) {
            simd::rope_rotate(row, &cos2[..self.dim], &nsin2[..self.dim]);
        }
    }

    /// One-row reference rotation (the pre-SIMD loop), kept for dims
    /// beyond the stack pattern capacity.
    fn apply_row_reference(&self, row: &mut [f32], pos: i64) {
        for (i, f) in self.inv_freq.iter().enumerate() {
            let angle = pos as f64 * f;
            let (sin64, cos64) = angle.sin_cos();
            let (sin, cos) = (sin64 as f32, cos64 as f32);
            let e = row[2 * i];
            let o = row[2 * i + 1];
            row[2 * i] = e * cos - o * sin;
            row[2 * i + 1] = e * sin + o * cos;
        }
    }
}

/// Build one K compression cache entry from a *complete* block of pre-RoPE
/// keys: {max,min,avg}-pool over the block, per-KV-head linear, RoPE at
/// the block-start position.
///
/// `k_block`: [Hkv, block, dh] row-major; `wk_gate`: [Hkv, 3*dh, dg].
/// Returns [Hkv, dg].
pub fn kcomp_entry(cfg: &ModelConfig, wk_gate: &[f32], k_block: &[f32],
                   block_size: usize, block_start: i64) -> Vec<f32> {
    let rope = RopeTable::new(cfg.d_gate, cfg.rope_theta);
    let mut pooled = Vec::new();
    let mut out = vec![0f32; cfg.n_kv_heads * cfg.d_gate];
    kcomp_entry_into(cfg, wk_gate, k_block, block_size, block_start, &rope,
                     &mut pooled, &mut out);
    out
}

/// Allocation-free variant of [`kcomp_entry`]: writes the [Hkv, dg] entry
/// into `out` using the caller's cached `rope` table and `pooled` scratch
/// (grown once, reused across flushes). The decode hot path
/// (`KcompCache::flush_block`) calls this.
pub fn kcomp_entry_into(cfg: &ModelConfig, wk_gate: &[f32], k_block: &[f32],
                        block_size: usize, block_start: i64, rope: &RopeTable,
                        pooled: &mut Vec<f32>, out: &mut [f32]) {
    let (hkv, dh, dg) = (cfg.n_kv_heads, cfg.head_dim, cfg.d_gate);
    debug_assert_eq!(k_block.len(), hkv * block_size * dh);
    debug_assert_eq!(wk_gate.len(), hkv * 3 * dh * dg);
    debug_assert_eq!(out.len(), hkv * dg);
    debug_assert_eq!(rope.dim(), dg);
    out.fill(0.0);
    pooled.resize(3 * dh, 0.0);
    for h in 0..hkv {
        let base = h * block_size * dh;
        for d in 0..dh {
            let mut mx = f32::NEG_INFINITY;
            let mut mn = f32::INFINITY;
            let mut sum = 0f32;
            for t in 0..block_size {
                let v = k_block[base + t * dh + d];
                mx = mx.max(v);
                mn = mn.min(v);
                sum += v;
            }
            pooled[d] = mx;
            pooled[dh + d] = mn;
            pooled[2 * dh + d] = sum / block_size as f32;
        }
        let w = &wk_gate[h * 3 * dh * dg..(h + 1) * 3 * dh * dg];
        let o = &mut out[h * dg..(h + 1) * dg];
        for (i, p) in pooled.iter().enumerate() {
            if *p == 0.0 {
                continue;
            }
            simd::axpy(o, &w[i * dg..(i + 1) * dg], *p);
        }
    }
    // Every head rotates at the same block-start position, so one apply
    // over the whole [hkv, dg] entry amortizes the per-call sin/cos
    // pattern setup across heads (per-row rotation is unchanged).
    rope.apply(out, block_start);
}

/// Gate block scores (logits): q_gate · KC^T / sqrt(dg).
///
/// `q_gate`: [Hkv, dg]; `kc`: [Hkv, n_entries, dg] (row-major, only
/// `n_complete` leading entries are valid). Returns [Hkv, n_complete].
pub fn gate_scores(cfg: &ModelConfig, q_gate: &[f32], kc: &[f32],
                   entries_stride: usize, n_complete: usize) -> Vec<f32> {
    let (hkv, dg) = (cfg.n_kv_heads, cfg.d_gate);
    let scale = 1.0 / (dg as f32).sqrt();
    let mut out = vec![0f32; hkv * n_complete];
    if n_complete == 0 {
        return out;
    }
    for h in 0..hkv {
        let q = &q_gate[h * dg..(h + 1) * dg];
        // Head-major entries: one contiguous multi-block FMA sweep.
        let rows = &kc[h * entries_stride * dg..][..n_complete * dg];
        simd::dot_rows(q, rows, dg, scale,
                       &mut out[h * n_complete..(h + 1) * n_complete]);
    }
    out
}

/// In-place softmax over each row of an [rows, n] matrix (threshold mode,
/// §3.1: the paper thresholds softmaxed scores). Max / sum / normalize
/// run on the dispatched SIMD kernels (fixed 8-lane reduction order on
/// every target, so SIMD and forced-scalar results are bit-identical).
pub fn softmax_rows(scores: &mut [f32], n: usize) {
    if n == 0 {
        return;
    }
    for row in scores.chunks_exact_mut(n) {
        simd::softmax_row(row);
    }
}

/// Oracle block scores for one decode query (the training ground truth,
/// §2.3, computed at inference): true attention probabilities over the
/// full cache, column-max within each block, max over the GQA group.
///
/// `q_rope`: [H, dh]; `k_at(head, t)` returns the cached RoPE'd key row.
/// Returns [Hkv, n_blocks_covering_len] (last entry may cover a partial
/// block).
pub fn oracle_scores(cfg: &ModelConfig, q_rope: &[f32],
                     k_at: &dyn Fn(usize, usize) -> *const f32, len: usize,
                     block_size: usize) -> Vec<f32> {
    let mut out = Vec::new();
    let mut logits = Vec::new();
    oracle_scores_into(cfg, q_rope, k_at, len, block_size, &mut out, &mut logits);
    out
}

/// Allocation-free variant of [`oracle_scores`]: writes the
/// `[Hkv, n_blocks]` scores into `out` and uses `logits` as the per-token
/// scratch row, both grown once and reused across calls. Bit-identical to
/// [`oracle_scores`] (same operations in the same order); the
/// `track_recall` / oracle selection hot loop calls this every step.
pub fn oracle_scores_into(cfg: &ModelConfig, q_rope: &[f32],
                          k_at: &dyn Fn(usize, usize) -> *const f32, len: usize,
                          block_size: usize, out: &mut Vec<f32>,
                          logits: &mut Vec<f32>) {
    let (h_all, hkv, g, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.group_size,
                               cfg.head_dim);
    let nblk = len.div_ceil(block_size);
    let scale = 1.0 / (dh as f32).sqrt();
    out.clear();
    out.resize(hkv * nblk, 0.0);
    logits.clear();
    logits.resize(len, 0.0);
    for qh in 0..h_all {
        let kvh = qh / g;
        let q = &q_rope[qh * dh..(qh + 1) * dh];
        let mut m = f32::NEG_INFINITY;
        for (t, lg) in logits.iter_mut().enumerate() {
            // SAFETY: k_at returns a pointer to a dh-long row that outlives
            // this call (the paged cache is not mutated during scoring).
            let krow = unsafe { std::slice::from_raw_parts(k_at(kvh, t), dh) };
            *lg = simd::dot(q, krow) * scale;
            m = m.max(*lg);
        }
        let mut denom = 0f32;
        for lg in logits.iter_mut() {
            *lg = (*lg - m).exp();
            denom += *lg;
        }
        let inv = 1.0 / denom.max(1e-30);
        for (t, lg) in logits.iter().enumerate() {
            let p = lg * inv;
            let j = t / block_size;
            let slot = &mut out[kvh * nblk + j];
            if p > *slot {
                *slot = p;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            vocab: 64, d_model: 64, n_layers: 1, n_heads: 4, n_kv_heads: 2,
            head_dim: 4, mlp_hidden: 8, rope_theta: 10000.0, rms_eps: 1e-5,
            d_gate: 4, block_size: 4, max_seq: 32, group_size: 2,
        }
    }

    #[test]
    fn rope_pos_zero_identity_and_norm() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        let orig = x.clone();
        rope_inplace(&mut x, 4, 0, 10000.0);
        assert_eq!(x, orig);
        rope_inplace(&mut x, 4, 12345, 10000.0);
        let n0: f32 = orig.iter().map(|v| v * v).sum();
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-4);
    }

    #[test]
    fn rope_relative_dot_product() {
        let q = [0.3f32, -1.2, 0.7, 0.1];
        let k = [1.0f32, 0.5, -0.4, 0.9];
        let dot = |m: i64, n: i64| {
            let mut qm = q.to_vec();
            let mut kn = k.to_vec();
            rope_inplace(&mut qm, 4, m, 10000.0);
            rope_inplace(&mut kn, 4, n, 10000.0);
            qm.iter().zip(&kn).map(|(a, b)| a * b).sum::<f32>()
        };
        assert!((dot(9, 5) - dot(104, 100)).abs() < 1e-4);
    }

    #[test]
    fn rope_table_bit_identical_to_reference() {
        let mut rng = crate::util::rng::Rng::new(17);
        for &dim in &[4usize, 8, 32] {
            let table = RopeTable::new(dim, 10000.0);
            for _ in 0..20 {
                let mut a: Vec<f32> =
                    (0..dim * 3).map(|_| rng.normal() as f32).collect();
                let mut b = a.clone();
                let pos = rng.below(100_000) as i64;
                rope_inplace(&mut a, dim, pos, 10000.0);
                table.apply(&mut b, pos);
                assert_eq!(a, b, "dim={dim} pos={pos}");
            }
        }
    }

    #[test]
    fn kcomp_entry_into_matches_alloc_version() {
        let c = cfg();
        let mut rng = crate::util::rng::Rng::new(23);
        let bs = 4;
        let k_block: Vec<f32> = (0..c.n_kv_heads * bs * c.head_dim)
            .map(|_| rng.normal() as f32)
            .collect();
        let wk: Vec<f32> = (0..c.n_kv_heads * 3 * c.head_dim * c.d_gate)
            .map(|_| rng.normal() as f32)
            .collect();
        let rope = RopeTable::new(c.d_gate, c.rope_theta);
        let mut pooled = Vec::new();
        let mut out = vec![0f32; c.n_kv_heads * c.d_gate];
        for start in [0i64, 4, 12, 640] {
            let expect = kcomp_entry(&c, &wk, &k_block, bs, start);
            // Dirty `out` to prove the _into variant fully overwrites it.
            out.fill(7.5);
            kcomp_entry_into(&c, &wk, &k_block, bs, start, &rope, &mut pooled,
                             &mut out);
            assert_eq!(out, expect, "start={start}");
        }
    }

    #[test]
    fn kcomp_constant_block() {
        // Constant keys: max == min == avg, so the projection reduces to
        // c * sum over the three pooled copies of each weight column.
        let c = cfg();
        let bs = 4;
        let k_block = vec![2.0f32; c.n_kv_heads * bs * c.head_dim];
        let wk = vec![0.5f32; c.n_kv_heads * 3 * c.head_dim * c.d_gate];
        let out = kcomp_entry(&c, &wk, &k_block, bs, 0);
        // each output = 2.0 * 0.5 * 3*dh = 12 (dh=4) => 12.0; pos 0 rope = id
        for x in out {
            assert!((x - 2.0 * 0.5 * 3.0 * c.head_dim as f32).abs() < 1e-4);
        }
    }

    #[test]
    fn gate_scores_manual() {
        let c = cfg();
        // Hkv=2, dg=4, two entries each.
        let qg = vec![1.0, 0.0, 0.0, 0.0, /*h1*/ 0.0, 1.0, 0.0, 0.0];
        let kc = vec![
            // h0 entries
            2.0, 0.0, 0.0, 0.0, 0.0, 4.0, 0.0, 0.0,
            // h1 entries
            0.0, 6.0, 0.0, 0.0, 8.0, 0.0, 0.0, 0.0,
        ];
        let s = gate_scores(&c, &qg, &kc, 2, 2);
        let scale = 1.0 / 2.0; // sqrt(4)
        assert!((s[0] - 2.0 * scale).abs() < 1e-6);
        assert!((s[1] - 0.0).abs() < 1e-6);
        assert!((s[2] - 6.0 * scale).abs() < 1e-6);
        assert!((s[3] - 0.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_rows_sums_to_one() {
        let mut s = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut s, 3);
        for row in s.chunks(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(row.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn oracle_scores_into_bit_identical_with_dirty_reused_buffers() {
        let c = cfg();
        let mut rng = crate::util::rng::Rng::new(41);
        let mut out = Vec::new();
        let mut logits = Vec::new();
        let max_tokens = 24;
        for step in 0..60 {
            // Context length drifts across block boundaries so both the
            // partial-last-block and shrinking-buffer cases are hit.
            let len = rng.range(1, max_tokens + 1);
            let kdata: Vec<f32> = (0..c.n_kv_heads * max_tokens * c.head_dim)
                .map(|_| rng.normal() as f32)
                .collect();
            let q: Vec<f32> = (0..c.n_heads * c.head_dim)
                .map(|_| rng.normal() as f32)
                .collect();
            let dh = c.head_dim;
            let k_at = |h: usize, t: usize| -> *const f32 {
                kdata[(h * max_tokens + t) * dh..].as_ptr()
            };
            let expect = oracle_scores(&c, &q, &k_at, len, c.block_size);
            // Poison the reused buffers to prove they are fully rewritten.
            out.resize(out.len().max(7), 0.0);
            out.fill(9.25);
            logits.fill(-3.5);
            oracle_scores_into(&c, &q, &k_at, len, c.block_size, &mut out,
                               &mut logits);
            assert_eq!(out, expect, "step={step} len={len}");
        }
    }

    #[test]
    fn oracle_scores_sum_le_one_and_peak_block() {
        let c = cfg();
        let len = 10; // 3 blocks (last partial)
        // Keys: token 5 identical to the query direction -> block 1 peaks.
        let mut kdata = vec![0f32; c.n_kv_heads * 16 * c.head_dim];
        for h in 0..c.n_kv_heads {
            kdata[(h * 16 + 5) * c.head_dim] = 5.0;
        }
        let q: Vec<f32> = (0..c.n_heads * c.head_dim)
            .map(|i| if i % c.head_dim == 0 { 3.0 } else { 0.0 })
            .collect();
        let dh = c.head_dim;
        let k_at = |h: usize, t: usize| -> *const f32 {
            kdata[(h * 16 + t) * dh..].as_ptr()
        };
        let s = oracle_scores(&c, &q, &k_at, len, c.block_size);
        assert_eq!(s.len(), c.n_kv_heads * 3);
        for h in 0..c.n_kv_heads {
            let row = &s[h * 3..(h + 1) * 3];
            assert!(row[1] > row[0] && row[1] > row[2], "{row:?}");
            assert!(row.iter().all(|p| (0.0..=1.0 + 1e-5).contains(p)));
        }
    }
}
