//! Flat ordered parameter store: load/save raw LE-f32 checkpoints in the
//! manifest layout, index tensors by name, and keep resident device
//! copies for the decode hot path.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::runtime::manifest::ParamSpec;
use crate::runtime::HostTensor;

pub struct ParamStore {
    pub specs: Vec<ParamSpec>,
    pub tensors: Vec<HostTensor>,
    index: HashMap<String, usize>,
}

impl ParamStore {
    pub fn zeros(specs: &[ParamSpec]) -> ParamStore {
        let tensors = specs
            .iter()
            .map(|s| HostTensor::zeros_f32(s.shape.clone()))
            .collect::<Vec<_>>();
        Self::from_tensors(specs, tensors)
    }

    pub fn from_tensors(specs: &[ParamSpec], tensors: Vec<HostTensor>) -> ParamStore {
        assert_eq!(specs.len(), tensors.len());
        let index = specs
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.clone(), i))
            .collect();
        ParamStore { specs: specs.to_vec(), tensors, index }
    }

    /// Load a raw little-endian f32 checkpoint in spec order.
    pub fn load(path: &Path, specs: &[ParamSpec]) -> Result<ParamStore> {
        let mut f = std::fs::File::open(path)
            .map_err(|e| anyhow!("open {}: {e}", path.display()))?;
        let mut tensors = Vec::with_capacity(specs.len());
        for s in specs {
            let n: usize = s.shape.iter().product();
            let mut buf = vec![0u8; 4 * n];
            f.read_exact(&mut buf)
                .map_err(|e| anyhow!("reading {} ({n} f32): {e}", s.name))?;
            let data: Vec<f32> = buf
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.push(HostTensor::f32(s.shape.clone(), data));
        }
        let mut rest = Vec::new();
        f.read_to_end(&mut rest)?;
        if !rest.is_empty() {
            bail!("checkpoint {} has {} trailing bytes", path.display(), rest.len());
        }
        Ok(Self::from_tensors(specs, tensors))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .map_err(|e| anyhow!("create {}: {e}", path.display()))?;
        for t in &self.tensors {
            let v = t.as_f32()?;
            let mut buf = Vec::with_capacity(4 * v.len());
            for x in v {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            f.write_all(&buf)?;
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        let i = *self
            .index
            .get(name)
            .ok_or_else(|| anyhow!("unknown parameter {name:?}"))?;
        Ok(&self.tensors[i])
    }

    /// Replace every tensor (training step output); shapes must match.
    pub fn set_all(&mut self, tensors: Vec<HostTensor>) -> Result<()> {
        if tensors.len() != self.tensors.len() {
            bail!("set_all: {} tensors, expected {}", tensors.len(), self.tensors.len());
        }
        for (t, s) in tensors.iter().zip(&self.specs) {
            t.check(&s.name, "f32", &s.shape)?;
        }
        self.tensors = tensors;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "a".into(), shape: vec![2, 3] },
            ParamSpec { name: "b".into(), shape: vec![4] },
        ]
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("seerattn_params_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        let mut ps = ParamStore::zeros(&specs());
        ps.set_all(vec![
            HostTensor::f32(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 7.25, -0.5]),
            HostTensor::f32(vec![4], vec![9.0, 8.0, 7.0, 6.0]),
        ])
        .unwrap();
        ps.save(&path).unwrap();
        let loaded = ParamStore::load(&path, &specs()).unwrap();
        assert_eq!(loaded.tensors, ps.tensors);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_truncated_and_oversized() {
        let dir = std::env::temp_dir().join(format!("seerattn_params2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, vec![0u8; 4 * 5]).unwrap(); // needs 4*10
        assert!(ParamStore::load(&path, &specs()).is_err());
        std::fs::write(&path, vec![0u8; 4 * 11]).unwrap(); // one extra f32
        assert!(ParamStore::load(&path, &specs()).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn get_and_set_validation() {
        let mut ps = ParamStore::zeros(&specs());
        assert!(ps.get("a").is_ok());
        assert!(ps.get("zz").is_err());
        assert_eq!(ps.numel(), 10);
        // Wrong shape rejected.
        let bad = vec![
            HostTensor::f32(vec![3, 2], vec![0.0; 6]),
            HostTensor::f32(vec![4], vec![0.0; 4]),
        ];
        assert!(ps.set_all(bad).is_err());
    }
}
