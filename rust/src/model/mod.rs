//! Model configuration (mirrors `python/compile/config.py`) and the flat
//! parameter store shared with the AOT layer.

pub mod params;

pub use params::ParamStore;

use anyhow::Result;

use crate::util::json::Json;

/// Architecture of the base GQA transformer + AttnGate, read back from
/// the manifest (single source of truth lives in Python).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub mlp_hidden: usize,
    pub rope_theta: f64,
    pub rms_eps: f64,
    pub d_gate: usize,
    pub block_size: usize,
    pub max_seq: usize,
    pub group_size: usize,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            vocab: j.get("vocab")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            n_kv_heads: j.get("n_kv_heads")?.as_usize()?,
            head_dim: j.get("head_dim")?.as_usize()?,
            mlp_hidden: j.get("mlp_hidden")?.as_usize()?,
            rope_theta: j.get("rope_theta")?.as_f64()?,
            rms_eps: j.get("rms_eps")?.as_f64()?,
            d_gate: j.get("d_gate")?.as_usize()?,
            block_size: j.get("block_size")?.as_usize()?,
            max_seq: j.get("max_seq")?.as_usize()?,
            group_size: j.get("group_size")?.as_usize()?,
        })
    }

    pub fn n_blocks(&self, block_size: usize) -> usize {
        self.max_seq / block_size
    }

    /// KV-cache bytes per token per layer (f32 K + V across kv heads).
    pub fn kv_bytes_per_token_layer(&self) -> usize {
        2 * self.n_kv_heads * self.head_dim * 4
    }

    /// K compression cache bytes per *block* per layer — the paper's §3.2
    /// overhead claim (<1% of KV at block 64) is checked in tests.
    pub fn kcomp_bytes_per_block_layer(&self) -> usize {
        self.n_kv_heads * self.d_gate * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig {
            vocab: 64,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 16,
            mlp_hidden: 128,
            rope_theta: 10000.0,
            rms_eps: 1e-5,
            d_gate: 32,
            block_size: 16,
            max_seq: 64,
            group_size: 2,
        }
    }

    #[test]
    fn from_json_roundtrip() {
        let c = tiny();
        let j = Json::parse(&format!(
            r#"{{"vocab":{},"d_model":{},"n_layers":{},"n_heads":{},
                 "n_kv_heads":{},"head_dim":{},"mlp_hidden":{},
                 "rope_theta":{},"rms_eps":{},"d_gate":{},"block_size":{},
                 "max_seq":{},"group_size":{}}}"#,
            c.vocab, c.d_model, c.n_layers, c.n_heads, c.n_kv_heads,
            c.head_dim, c.mlp_hidden, c.rope_theta, c.rms_eps, c.d_gate,
            c.block_size, c.max_seq, c.group_size
        ))
        .unwrap();
        assert_eq!(ModelConfig::from_json(&j).unwrap(), c);
    }

    #[test]
    fn kcomp_overhead_matches_paper_ratio() {
        // Paper §3.2: at block 64 and d_gate == head_dim/..., the K
        // compression cache is ~1/128 of KV. Generalised:
        // ratio = d_gate / (2 * head_dim * block).
        let c = tiny();
        let kv_per_block = c.kv_bytes_per_token_layer() * 64;
        let kc_per_block = c.kcomp_bytes_per_block_layer();
        let ratio = kc_per_block as f64 / kv_per_block as f64;
        let expect = c.d_gate as f64 / (2.0 * c.head_dim as f64 * 64.0);
        assert!((ratio - expect).abs() < 1e-12);
        assert!(ratio < 0.02, "compression cache should be ~1% of KV");
    }
}
