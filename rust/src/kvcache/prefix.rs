//! Content-addressed prefix cache over block-granular prompt hashes.
//!
//! Real fleets serve millions of requests sharing system prompts and
//! few-shot prefixes; recomputing their KV (and the SeerAttention-R
//! gate's compressed-K blocks) per request is pure waste. The paper's
//! sparse block sizes make prefixes naturally content-addressable at
//! block granularity: one cached block ⇔ one KV page per layer ⇔ one
//! kcomp gate entry per head per layer.
//!
//! [`PrefixCache`] is a radix index keyed by **rolling block hashes**:
//! the chain hash of a `k`-block prefix is `chain_hash` folded over
//! block `k`'s tokens seeded with the `(k-1)`-block chain hash, so the
//! key *is* the content address of the whole prefix and the radix trie
//! is implicit — every node stores its parent's hash, and longest-prefix
//! lookup walks the chain forward until a block is missing or the prompt
//! runs out of full blocks.
//!
//! Sharing is **immutable by construction**: only *full* prompt blocks
//! are ever published, and sequences append strictly beyond their prompt
//! (the divergence block and everything after it live in freshly
//! allocated private pages). That is the copy-on-write discipline at the
//! divergence point — shared pages are never written, so no copy is ever
//! needed.
//!
//! Lifetime rules, which the chaos suite leans on:
//! - a node used by a live sequence is **pinned** (refcounted) and can
//!   never be evicted, no matter the pressure;
//! - eviction is **leaf-first LRU** over unpinned nodes (a mid-chain
//!   node is only evictable once every longer chain through it is gone),
//!   so a lookup can always trust a present chain to be contiguous;
//! - the cache yields blocks back under memory pressure *before* the
//!   engine defers admissions or preempts live sequences.
//!
//! The payload is generic: the deterministic `SimEngine` caches its
//! folded token-function state per block boundary (plus one simulated
//! page), the real engine caches per-layer `PageId`s together with the
//! head-major kcomp gate rows and Quest min/max metadata for the block.

use std::collections::HashMap;

/// Chain-hash seed for the empty prefix (the radix root).
pub const ROOT_HASH: u64 = 0xC0FF_EE00_5EED_0001;

/// Roll `parent` (the chain hash of the preceding blocks) over one
/// block's tokens. FNV-1a over the token bytes, then a SplitMix64-style
/// finalizer so single-token differences diffuse through all 64 bits
/// (the low bits feed shard routing via `% shards`).
pub fn chain_hash(parent: u64, tokens: &[i32]) -> u64 {
    let mut h = parent ^ 0xCBF2_9CE4_8422_2325;
    for &t in tokens {
        for b in (t as u32).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01B3);
        }
    }
    h ^= h >> 30;
    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94D0_49BB_1331_11EB);
    h ^ (h >> 31)
}

/// Chain hash of the first full block of `prompt` (the whole prompt if
/// shorter than one block) — the prefix-affinity routing key: requests
/// sharing a first block land on the shard where that prefix is warm.
pub fn first_block_hash(prompt: &[i32], block_size: usize) -> u64 {
    let take = if block_size == 0 { prompt.len() } else { prompt.len().min(block_size) };
    chain_hash(ROOT_HASH, &prompt[..take])
}

struct Node<P> {
    parent: u64,
    payload: P,
    /// Live sequences whose admitted prefix includes this block.
    pinned: u32,
    /// Cached blocks whose parent is this node (leaf ⇔ 0).
    children: u32,
    last_use: u64,
}

/// A longest-cached-prefix lookup result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixHit {
    /// Full blocks of the prompt found cached (0 = miss).
    pub blocks: usize,
    /// Chain hash of the deepest cached block ([`ROOT_HASH`] on miss).
    pub hash: u64,
}

/// Content-addressed radix index of cached prefix blocks. See the
/// module docs for the sharing and eviction rules.
pub struct PrefixCache<P> {
    block_size: usize,
    /// Max cached blocks (0 = unbounded); LRU-evicted beyond.
    cap_blocks: usize,
    nodes: HashMap<u64, Node<P>>,
    tick: u64,
}

impl<P> PrefixCache<P> {
    pub fn new(block_size: usize, cap_blocks: usize) -> PrefixCache<P> {
        assert!(block_size > 0, "prefix cache needs a block size");
        PrefixCache { block_size, cap_blocks, nodes: HashMap::new(), tick: 0 }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Cached blocks currently resident.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    fn touch(&mut self, hash: u64) {
        self.tick += 1;
        let tick = self.tick;
        if let Some(n) = self.nodes.get_mut(&hash) {
            n.last_use = tick;
        }
    }

    /// Longest cached block-aligned prefix of `prompt`. Refreshes the
    /// LRU clock of every node on the hit chain; does NOT pin.
    pub fn lookup(&mut self, prompt: &[i32]) -> PrefixHit {
        let full = prompt.len() / self.block_size;
        let mut hash = ROOT_HASH;
        let mut blocks = 0;
        for b in 0..full {
            let next =
                chain_hash(hash, &prompt[b * self.block_size..(b + 1) * self.block_size]);
            if !self.nodes.contains_key(&next) {
                break;
            }
            self.touch(next);
            hash = next;
            blocks = b + 1;
        }
        PrefixHit { blocks, hash }
    }

    /// Non-mutating [`lookup`](PrefixCache::lookup): same longest-prefix
    /// walk without refreshing the LRU clock — for admission-readiness
    /// probes that must take `&self`.
    pub fn probe(&self, prompt: &[i32]) -> PrefixHit {
        let full = prompt.len() / self.block_size;
        let mut hash = ROOT_HASH;
        let mut blocks = 0;
        for b in 0..full {
            let next =
                chain_hash(hash, &prompt[b * self.block_size..(b + 1) * self.block_size]);
            if !self.nodes.contains_key(&next) {
                break;
            }
            hash = next;
            blocks = b + 1;
        }
        PrefixHit { blocks, hash }
    }

    /// Chain hash `up` blocks above `hash` ([`ROOT_HASH`] at the top).
    /// Used to trim a lookup hit to a shorter reuse depth.
    pub fn ancestor(&self, hash: u64, up: usize) -> u64 {
        let mut h = hash;
        for _ in 0..up {
            h = self.nodes.get(&h).expect("ancestor of missing prefix node").parent;
        }
        h
    }

    /// Blocks evictable right now — i.e. unpinned. Because every pin
    /// covers a full chain from the root, an unpinned node can have no
    /// pinned descendant, so cascade (leaf-first) eviction can reach
    /// every unpinned node: this count is exact, not a bound.
    pub fn evictable(&self) -> usize {
        self.nodes.values().filter(|n| n.pinned == 0).count()
    }

    /// How many of the `blocks`-long chain ending at `hash` are
    /// currently unpinned (resident only as cache, chargeable to the
    /// next sequence that pins them).
    pub fn chain_unpinned(&self, hash: u64, blocks: usize) -> usize {
        let mut h = hash;
        let mut n = 0;
        for _ in 0..blocks {
            let node = self.nodes.get(&h).expect("broken prefix chain");
            if node.pinned == 0 {
                n += 1;
            }
            h = node.parent;
        }
        n
    }

    /// Payload of the chain ending at `hash`, shallowest block first
    /// (`blocks` entries). Panics if the chain is shorter than claimed —
    /// a pinned chain can never lose a node, so a caller that pinned
    /// first is safe.
    pub fn chain_payloads(&self, hash: u64, blocks: usize) -> Vec<&P> {
        let mut out = Vec::with_capacity(blocks);
        let mut h = hash;
        for _ in 0..blocks {
            let n = self.nodes.get(&h).expect("broken prefix chain");
            out.push(&n.payload);
            h = n.parent;
        }
        debug_assert_eq!(h, ROOT_HASH, "chain deeper than claimed");
        out.reverse();
        out
    }

    /// Payload of the single node at `hash`.
    pub fn payload(&self, hash: u64) -> Option<&P> {
        self.nodes.get(&hash).map(|n| &n.payload)
    }

    /// Pin the `blocks`-long chain ending at `hash` for a live sequence.
    /// Every node on the chain gains one reference; none of them can be
    /// evicted until [`PrefixCache::unpin`] with the same arguments.
    pub fn pin(&mut self, hash: u64, blocks: usize) {
        let mut h = hash;
        for _ in 0..blocks {
            let n = self.nodes.get_mut(&h).expect("pin of missing prefix node");
            n.pinned += 1;
            h = n.parent;
        }
        debug_assert_eq!(h, ROOT_HASH);
    }

    /// Drop a live sequence's references on the chain ending at `hash`.
    pub fn unpin(&mut self, hash: u64, blocks: usize) {
        let mut h = hash;
        for _ in 0..blocks {
            let n = self.nodes.get_mut(&h).expect("unpin of missing prefix node");
            debug_assert!(n.pinned > 0, "prefix refcount underflow");
            n.pinned = n.pinned.saturating_sub(1);
            h = n.parent;
        }
        debug_assert_eq!(h, ROOT_HASH);
    }

    /// Publish one block: `hash` must be `chain_hash(parent, block)` and
    /// `parent` must be [`ROOT_HASH`] or already cached. Returns `false`
    /// (payload dropped, caller keeps its private copy) if the block is
    /// already cached — first publisher wins, so two sequences that
    /// prefilled the same prefix concurrently never double-insert. On
    /// success the node starts with **one pin held by the publisher**
    /// (count it in the publisher's pinned-chain length). If the cap is
    /// exceeded, unpinned LRU leaves are evicted into `evicted`.
    pub fn insert(&mut self, parent: u64, hash: u64, payload: P,
                  evicted: &mut Vec<P>) -> bool {
        if self.nodes.contains_key(&hash) {
            return false;
        }
        if parent != ROOT_HASH {
            let Some(p) = self.nodes.get_mut(&parent) else {
                // Parent got evicted between lookup and publish (the
                // publisher only pins blocks it reused, not blocks it is
                // about to publish): refuse rather than orphan a node
                // lookups could never reach contiguously.
                return false;
            };
            p.children += 1;
        }
        self.tick += 1;
        self.nodes.insert(hash, Node {
            parent,
            payload,
            pinned: 1,
            children: 0,
            last_use: self.tick,
        });
        if self.cap_blocks > 0 {
            while self.nodes.len() > self.cap_blocks {
                match self.evict_one() {
                    Some(p) => evicted.push(p),
                    None => break, // everything left is pinned
                }
            }
        }
        true
    }

    /// Evict the least-recently-used unpinned **leaf** (a mid-chain node
    /// only becomes a leaf once its longer chains are gone, keeping every
    /// resident chain contiguous). Returns its payload so the caller can
    /// free the pages it owned, or `None` if nothing is evictable.
    pub fn evict_one(&mut self) -> Option<P> {
        let victim = self
            .nodes
            .iter()
            .filter(|(_, n)| n.pinned == 0 && n.children == 0)
            .min_by_key(|(_, n)| n.last_use)
            .map(|(h, _)| *h)?;
        let node = self.nodes.remove(&victim).unwrap();
        if node.parent != ROOT_HASH {
            if let Some(p) = self.nodes.get_mut(&node.parent) {
                p.children -= 1;
            }
        }
        Some(node.payload)
    }

    /// Evict up to `want` unpinned blocks (pressure path: the engine
    /// calls this to yield pages back before deferring or preempting).
    pub fn evict(&mut self, want: usize, evicted: &mut Vec<P>) -> usize {
        let mut n = 0;
        while n < want {
            match self.evict_one() {
                Some(p) => {
                    evicted.push(p);
                    n += 1;
                }
                None => break,
            }
        }
        n
    }

    /// Evict every unpinned block (drain/shutdown; cascades through
    /// parents as their chains disappear).
    pub fn evict_all(&mut self, evicted: &mut Vec<P>) -> usize {
        self.evict(usize::MAX, evicted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prompt(n: usize, salt: i32) -> Vec<i32> {
        (0..n as i32).map(|t| t * 7 + salt).collect()
    }

    /// Publish every full block of `p`, pinning the whole chain; returns
    /// (deepest hash, blocks).
    fn publish(c: &mut PrefixCache<usize>, p: &[i32]) -> (u64, usize) {
        let bs = c.block_size();
        let mut hash = ROOT_HASH;
        let mut evicted = Vec::new();
        let mut published = 0usize;
        for b in 0..p.len() / bs {
            let next = chain_hash(hash, &p[b * bs..(b + 1) * bs]);
            if c.payload(next).is_none() {
                assert!(c.insert(hash, next, b, &mut evicted));
                published += 1;
            } else {
                c.pin(next, 1);
            }
            hash = next;
        }
        let _ = published;
        (hash, p.len() / bs)
    }

    #[test]
    fn chain_hash_is_deterministic_and_content_sensitive() {
        let a = chain_hash(ROOT_HASH, &[1, 2, 3, 4]);
        assert_eq!(a, chain_hash(ROOT_HASH, &[1, 2, 3, 4]));
        assert_ne!(a, chain_hash(ROOT_HASH, &[1, 2, 3, 5]));
        assert_ne!(a, chain_hash(a, &[1, 2, 3, 4]), "position-sensitive");
        assert_ne!(first_block_hash(&[1, 2], 4), first_block_hash(&[1, 3], 4),
                   "short prompts still route by content");
    }

    #[test]
    fn lookup_finds_longest_prefix_and_stops_at_divergence() {
        let mut c: PrefixCache<usize> = PrefixCache::new(4, 0);
        let p = prompt(12, 0); // 3 full blocks
        let (hash, blocks) = publish(&mut c, &p);
        assert_eq!(blocks, 3);
        assert_eq!(c.len(), 3);
        // Exact prefix: all 3 blocks hit, payloads in block order.
        let hit = c.lookup(&p);
        assert_eq!(hit, PrefixHit { blocks: 3, hash });
        let chain: Vec<usize> =
            c.chain_payloads(hit.hash, hit.blocks).into_iter().copied().collect();
        assert_eq!(chain, vec![0, 1, 2]);
        // Diverges inside block 1: only block 0 reusable.
        let mut q = p.clone();
        q[5] += 1;
        assert_eq!(c.lookup(&q).blocks, 1);
        // Longer prompt sharing all 3 blocks plus a tail: still 3.
        let mut r = p.clone();
        r.extend_from_slice(&[99, 98]);
        assert_eq!(c.lookup(&r).blocks, 3);
        // Sub-block prompt: no full block to reuse.
        assert_eq!(c.lookup(&p[..3]).blocks, 0);
    }

    #[test]
    fn eviction_is_leaf_first_lru_and_respects_pins() {
        let mut c: PrefixCache<usize> = PrefixCache::new(4, 0);
        let p = prompt(12, 0);
        let (hash, blocks) = publish(&mut c, &p); // pinned chain of 3
        // Nothing evictable while pinned.
        assert!(c.evict_one().is_none());
        c.unpin(hash, blocks);
        // Leaf first: block 2 (deepest) goes before block 0.
        let first = c.evict_one().unwrap();
        assert_eq!(first, 2);
        assert_eq!(c.lookup(&p).blocks, 2, "remaining chain stays contiguous");
        let mut ev = Vec::new();
        assert_eq!(c.evict_all(&mut ev), 2);
        assert!(c.is_empty());
    }

    #[test]
    fn cap_evicts_lru_unpinned_on_insert() {
        let mut c: PrefixCache<usize> = PrefixCache::new(2, 2);
        let a = prompt(4, 0); // 2 blocks
        let (ha, ba) = publish(&mut c, &a);
        c.unpin(ha, ba);
        // Publishing a different 2-block prefix overflows the cap: the
        // LRU leaves of `a` get evicted to make room.
        let b = prompt(4, 100);
        let (hb, bb) = publish(&mut c, &b);
        assert_eq!(c.len(), 2);
        assert_eq!(c.lookup(&b).blocks, 2, "new pinned chain survives");
        assert_eq!(c.lookup(&a).blocks, 0, "old chain evicted");
        c.unpin(hb, bb);
    }

    #[test]
    fn first_publisher_wins_and_pins_stack() {
        let mut c: PrefixCache<usize> = PrefixCache::new(4, 0);
        let p = prompt(4, 0);
        let h = chain_hash(ROOT_HASH, &p);
        let mut ev = Vec::new();
        assert!(c.insert(ROOT_HASH, h, 7, &mut ev));
        assert!(!c.insert(ROOT_HASH, h, 8, &mut ev), "second publisher loses");
        assert_eq!(c.payload(h), Some(&7));
        c.pin(h, 1); // a second sequence reuses it
        c.unpin(h, 1);
        assert!(c.evict_one().is_none(), "publisher pin still held");
        c.unpin(h, 1);
        assert_eq!(c.evict_one(), Some(7));
    }

    #[test]
    fn probe_ancestor_and_pin_accounting_agree() {
        let mut c: PrefixCache<usize> = PrefixCache::new(4, 0);
        let p = prompt(12, 0);
        let (hash, blocks) = publish(&mut c, &p);
        assert_eq!(c.probe(&p), PrefixHit { blocks, hash },
                   "probe matches lookup without touching");
        assert_eq!(c.ancestor(hash, blocks), ROOT_HASH);
        let h1 = c.ancestor(hash, 2); // depth-1 hash
        assert_eq!(h1, chain_hash(ROOT_HASH, &p[..4]));
        // Whole chain pinned by the publisher: nothing evictable.
        assert_eq!(c.evictable(), 0);
        assert_eq!(c.chain_unpinned(hash, blocks), 0);
        c.unpin(hash, blocks);
        assert_eq!(c.evictable(), 3);
        assert_eq!(c.chain_unpinned(hash, blocks), 3);
        // Re-pin a 1-block prefix of the chain: the deeper 2 stay
        // evictable.
        c.pin(h1, 1);
        assert_eq!(c.evictable(), 2);
        assert_eq!(c.chain_unpinned(hash, blocks), 2);
        c.unpin(h1, 1);
    }

    #[test]
    fn insert_without_resident_parent_is_refused() {
        let mut c: PrefixCache<usize> = PrefixCache::new(4, 0);
        let p = prompt(8, 0);
        let h0 = chain_hash(ROOT_HASH, &p[..4]);
        let h1 = chain_hash(h0, &p[4..8]);
        let mut ev = Vec::new();
        assert!(!c.insert(h0, h1, 1, &mut ev),
                "a node whose parent is gone would be unreachable");
        assert!(c.is_empty());
    }
}
