//! KV-cache subsystem: the paged block pool (vLLM-style), the paper's
//! K Compression Cache (§3.2), and a tiered-offload cost simulator.

pub mod kcomp;
pub mod offload;
pub mod paged;
pub mod prefix;

pub use kcomp::KcompCache;
pub use paged::{PageId, PagedKvPool, SeqKv};
pub use prefix::{chain_hash, first_block_hash, PrefixCache, PrefixHit, ROOT_HASH};
