//! Paged KV cache: a fixed pool of block-granular pages plus per-sequence
//! block tables, mirroring PagedAttention-style serving systems. One page
//! holds `block_size` tokens of K *and* V for all KV heads of one layer.
//!
//! The sparse decode path only ever gathers *selected* pages into the
//! executable staging buffer — the paper's I/O argument (cost scales with
//! the budget, not the context) is realised here as memcpy volume.

use anyhow::{bail, Result};

pub type PageId = u32;

/// Fixed-size page: K and V for `block_size` tokens.
/// Layout of each of k/v: [hkv, block_size, dh] row-major.
struct Page {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// Pool of pages with a free list and per-page refcounts. A page starts
/// at one reference on `allocate`; the prefix cache shares it across
/// sequences via `retain`, and `release` only returns it to the free
/// list when the last reference drops — sharers never copy (prefix
/// pages are immutable by construction, see `kvcache::prefix`).
pub struct PagedKvPool {
    pages: Vec<Page>,
    free: Vec<PageId>,
    refs: Vec<u32>,
    /// Debug-only O(1) double-free guard (replaces an O(pool) scan of
    /// the free list that made debug-mode chaos runs quadratic).
    #[cfg(debug_assertions)]
    free_map: Vec<bool>,
    pub hkv: usize,
    pub dh: usize,
    pub block_size: usize,
}

impl PagedKvPool {
    pub fn new(capacity: usize, hkv: usize, dh: usize, block_size: usize) -> PagedKvPool {
        let elems = hkv * block_size * dh;
        let pages = (0..capacity)
            .map(|_| Page { k: vec![0.0; elems], v: vec![0.0; elems] })
            .collect();
        let free = (0..capacity as u32).rev().collect();
        PagedKvPool {
            pages,
            free,
            refs: vec![0; capacity],
            #[cfg(debug_assertions)]
            free_map: vec![true; capacity],
            hkv,
            dh,
            block_size,
        }
    }

    pub fn capacity(&self) -> usize {
        self.pages.len()
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn allocate(&mut self) -> Result<PageId> {
        match self.free.pop() {
            Some(id) => {
                self.refs[id as usize] = 1;
                #[cfg(debug_assertions)]
                {
                    self.free_map[id as usize] = false;
                }
                Ok(id)
            }
            None => bail!("KV page pool exhausted ({} pages)", self.pages.len()),
        }
    }

    /// Add a reference to an allocated page (prefix-cache sharing).
    pub fn retain(&mut self, id: PageId) {
        debug_assert!(self.refs[id as usize] > 0, "retain of free page {id}");
        self.refs[id as usize] += 1;
    }

    /// References currently held on `id` (0 = free).
    pub fn ref_count(&self, id: PageId) -> u32 {
        self.refs[id as usize]
    }

    /// Drop one reference; the page returns to the free list only when
    /// the last holder releases it.
    pub fn release(&mut self, id: PageId) {
        #[cfg(debug_assertions)]
        {
            debug_assert!(!self.free_map[id as usize], "double free of page {id}");
        }
        debug_assert!(self.refs[id as usize] > 0, "release of free page {id}");
        self.refs[id as usize] -= 1;
        if self.refs[id as usize] == 0 {
            #[cfg(debug_assertions)]
            {
                self.free_map[id as usize] = true;
            }
            self.free.push(id);
        }
    }

    /// Write one token's K/V rows (`k`/`v`: [hkv, dh]) at `slot` within a
    /// page.
    pub fn write_token(&mut self, id: PageId, slot: usize, k: &[f32], v: &[f32]) {
        debug_assert!(slot < self.block_size);
        debug_assert_eq!(k.len(), self.hkv * self.dh);
        let page = &mut self.pages[id as usize];
        for h in 0..self.hkv {
            let dst = (h * self.block_size + slot) * self.dh;
            page.k[dst..dst + self.dh].copy_from_slice(&k[h * self.dh..(h + 1) * self.dh]);
            page.v[dst..dst + self.dh].copy_from_slice(&v[h * self.dh..(h + 1) * self.dh]);
        }
    }

    /// Key row pointer for (page, kv head, slot) — used by the oracle
    /// scorer to walk the cache without copying.
    pub fn k_row(&self, id: PageId, h: usize, slot: usize) -> &[f32] {
        let page = &self.pages[id as usize];
        let off = (h * self.block_size + slot) * self.dh;
        &page.k[off..off + self.dh]
    }

    /// Copy `n_tokens` of one KV head's K and V from a page into staging
    /// slices (each of len n_tokens * dh) — the decode gather's block
    /// copy, routed through the dispatched SIMD copy kernel.
    pub fn gather_block(&self, id: PageId, h: usize, n_tokens: usize,
                        k_out: &mut [f32], v_out: &mut [f32]) {
        debug_assert!(n_tokens <= self.block_size);
        let page = &self.pages[id as usize];
        let off = h * self.block_size * self.dh;
        let n = n_tokens * self.dh;
        crate::util::simd::copy(&mut k_out[..n], &page.k[off..off + n]);
        crate::util::simd::copy(&mut v_out[..n], &page.v[off..off + n]);
    }
}

/// Per-sequence view: block table + length, owning page allocation.
pub struct SeqKv {
    pub pages: Vec<PageId>,
    pub len: usize,
}

impl SeqKv {
    pub fn new() -> SeqKv {
        SeqKv { pages: Vec::new(), len: 0 }
    }

    /// Append one token's K/V, allocating a fresh page at block
    /// boundaries.
    pub fn append(&mut self, pool: &mut PagedKvPool, k: &[f32], v: &[f32]) -> Result<()> {
        let slot = self.len % pool.block_size;
        if slot == 0 {
            self.pages.push(pool.allocate()?);
        }
        let page = *self.pages.last().unwrap();
        pool.write_token(page, slot, k, v);
        self.len += 1;
        Ok(())
    }

    /// Tokens resident in block `blk` (the last block may be partial).
    pub fn tokens_in_block(&self, blk: usize, block_size: usize) -> usize {
        let start = blk * block_size;
        debug_assert!(start < self.len);
        (self.len - start).min(block_size)
    }

    pub fn n_blocks(&self) -> usize {
        self.pages.len()
    }

    /// Release all pages back to the pool.
    pub fn release(&mut self, pool: &mut PagedKvPool) {
        for p in self.pages.drain(..) {
            pool.release(p);
        }
        self.len = 0;
    }
}

impl Default for SeqKv {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn pool() -> PagedKvPool {
        PagedKvPool::new(8, 2, 4, 4)
    }

    #[test]
    fn append_allocates_pages_at_boundaries() {
        let mut p = pool();
        let mut s = SeqKv::new();
        let k = vec![1.0; 8];
        let v = vec![2.0; 8];
        for t in 0..9 {
            s.append(&mut p, &k, &v).unwrap();
            assert_eq!(s.n_blocks(), t / 4 + 1);
        }
        assert_eq!(p.free_pages(), 8 - 3);
        s.release(&mut p);
        assert_eq!(p.free_pages(), 8);
        assert_eq!(s.len, 0);
    }

    #[test]
    fn pool_exhaustion_errors() {
        let mut p = PagedKvPool::new(1, 2, 4, 4);
        let mut s = SeqKv::new();
        let k = vec![0.0; 8];
        for _ in 0..4 {
            s.append(&mut p, &k, &k).unwrap();
        }
        assert!(s.append(&mut p, &k, &k).is_err());
    }

    #[test]
    fn gather_roundtrips_written_tokens() {
        let mut p = pool();
        let mut s = SeqKv::new();
        let mut rng = Rng::new(5);
        let mut truth_k: Vec<Vec<f32>> = Vec::new();
        for _ in 0..7 {
            let k: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
            let v: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
            truth_k.push(k.clone());
            s.append(&mut p, &k, &v).unwrap();
        }
        // Gather block 1 (tokens 4..7, 3 tokens) of head 1.
        let n = s.tokens_in_block(1, 4);
        assert_eq!(n, 3);
        let mut ko = vec![0.0; n * 4];
        let mut vo = vec![0.0; n * 4];
        p.gather_block(s.pages[1], 1, n, &mut ko, &mut vo);
        for t in 0..n {
            assert_eq!(&ko[t * 4..(t + 1) * 4], &truth_k[4 + t][4..8]);
        }
        // k_row agrees with gather.
        assert_eq!(p.k_row(s.pages[1], 1, 0), &ko[0..4]);
        s.release(&mut p);
    }

    #[test]
    fn retained_page_survives_until_last_release() {
        let mut p = pool();
        let id = p.allocate().unwrap();
        assert_eq!(p.ref_count(id), 1);
        p.retain(id); // a second sequence maps the same prefix page
        p.retain(id);
        assert_eq!(p.ref_count(id), 3);
        p.release(id);
        p.release(id);
        assert_eq!(p.free_pages(), 7, "still held by one sharer");
        p.release(id);
        assert_eq!(p.ref_count(id), 0);
        assert_eq!(p.free_pages(), 8);
        // The page can be handed out again after the last release.
        let again = p.allocate().unwrap();
        assert_eq!(again, id);
        p.release(again);
    }

    #[test]
    fn seq_release_drops_one_reference_per_page() {
        // Two SeqKv views sharing a prefix page: releasing one sequence
        // must not free the page under the other.
        let mut p = pool();
        let k = vec![1.0; 8];
        let mut a = SeqKv::new();
        for _ in 0..4 {
            a.append(&mut p, &k, &k).unwrap();
        }
        let shared = a.pages[0];
        p.retain(shared);
        let mut b = SeqKv { pages: vec![shared], len: 4 };
        a.release(&mut p);
        assert_eq!(p.ref_count(shared), 1);
        assert_eq!(p.free_pages(), 7);
        b.release(&mut p);
        assert_eq!(p.free_pages(), 8);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_is_caught_in_debug() {
        let mut p = pool();
        let id = p.allocate().unwrap();
        p.release(id);
        p.release(id);
    }

    #[test]
    fn property_no_double_allocation() {
        let mut p = PagedKvPool::new(16, 1, 2, 2);
        let mut rng = Rng::new(42);
        let mut seqs: Vec<SeqKv> = (0..4).map(|_| SeqKv::new()).collect();
        let k = vec![0.0; 2];
        for _ in 0..300 {
            let i = rng.below(seqs.len());
            if rng.bool(0.7) {
                let _ = seqs[i].append(&mut p, &k, &k);
            } else {
                seqs[i].release(&mut p);
            }
            // Invariant: every allocated page is owned by exactly one seq.
            let mut owned: Vec<PageId> = seqs.iter().flat_map(|s| s.pages.clone()).collect();
            owned.sort_unstable();
            let before = owned.len();
            owned.dedup();
            assert_eq!(owned.len(), before, "page owned twice");
            assert_eq!(owned.len() + p.free_pages(), p.capacity());
        }
    }
}
