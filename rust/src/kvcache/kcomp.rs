//! K Compression Cache (paper §3.2): cached compressed key representations
//! (pool + linear + RoPE per complete block) so the AttnGate never
//! recomputes its K branch for past tokens.
//!
//! Update protocol (two phases, exactly as the paper describes):
//!   1. While the sequence length is not a multiple of the block size, the
//!      newest (partial) block has no cache entry — the engine must always
//!      activate that block to avoid accuracy loss.
//!   2. Once `block_size` new tokens have accumulated, the pending
//!      pre-RoPE keys pass through pooling + linear once and append one
//!      entry.

use crate::gate::{self, RopeTable};
use crate::model::ModelConfig;
use crate::util::simd;

#[derive(Debug, Clone)]
pub struct KcompCache {
    hkv: usize,
    dh: usize,
    dg: usize,
    block_size: usize,
    /// Completed entries, **head-major** layout `[hkv, cap, dg]` with
    /// `cap = max_blocks`, fully allocated up front. Per head, the
    /// leading `n_complete` entries are valid and contiguous, so decode
    /// scoring (`score_into`) is one multi-block FMA sweep per head over
    /// sequential memory — the layout the SIMD kernels want. An append
    /// scatters one `dg`-row per head (hkv strided writes per flushed
    /// block; amortized over `block_size` tokens).
    entries: Vec<f32>,
    /// Entry capacity per head (the `cap` stride of `entries`).
    cap: usize,
    n_complete: usize,
    /// Pending pre-RoPE keys of the current partial block:
    /// [t_in_block, hkv, dh].
    pending: Vec<f32>,
    pending_tokens: usize,
    len: usize,
    /// Cached per-(d_gate, theta) RoPE frequencies — kills the
    /// `theta.powf(..)` in the flush inner loop.
    rope: RopeTable,
    /// Flush scratch: [hkv, block, dh] transpose of `pending`, plus the
    /// 3*dh pooled row. Grown once, reused for every flushed block.
    block_scratch: Vec<f32>,
    pooled_scratch: Vec<f32>,
    /// Flush scratch: the contiguous `[hkv, dg]` entry `kcomp_entry_into`
    /// produces before the per-head scatter into `entries`.
    entry_scratch: Vec<f32>,
}

impl KcompCache {
    pub fn new(cfg: &ModelConfig, block_size: usize) -> KcompCache {
        Self::with_max_seq(cfg, block_size, cfg.max_seq)
    }

    /// Like [`new`](KcompCache::new) but sized for an explicit context
    /// length — the engine passes its manifest context (`prefill_len`),
    /// which may exceed `cfg.max_seq`. The head-major entry store is
    /// capacity-allocated, so the cap must cover every block the
    /// sequence can ever complete.
    pub fn with_max_seq(cfg: &ModelConfig, block_size: usize,
                        max_seq: usize) -> KcompCache {
        let max_blocks = max_seq.max(cfg.max_seq).div_ceil(block_size);
        KcompCache {
            hkv: cfg.n_kv_heads,
            dh: cfg.head_dim,
            dg: cfg.d_gate,
            block_size,
            entries: vec![0.0; max_blocks * cfg.n_kv_heads * cfg.d_gate],
            cap: max_blocks,
            n_complete: 0,
            pending: Vec::with_capacity(block_size * cfg.n_kv_heads * cfg.head_dim),
            pending_tokens: 0,
            len: 0,
            rope: RopeTable::new(cfg.d_gate, cfg.rope_theta),
            block_scratch: Vec::new(),
            pooled_scratch: Vec::new(),
            entry_scratch: vec![0.0; cfg.n_kv_heads * cfg.d_gate],
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn n_complete(&self) -> usize {
        self.n_complete
    }

    /// True while the tail of the sequence is a partial block that must be
    /// force-activated.
    pub fn has_partial(&self) -> bool {
        self.pending_tokens > 0
    }

    /// Index of the partial block (valid when has_partial()).
    pub fn partial_index(&self) -> i32 {
        self.n_complete as i32
    }

    /// Append one token's pre-RoPE keys (`k_pre`: [hkv, dh]); compresses
    /// and caches the block when it completes.
    pub fn append(&mut self, cfg: &ModelConfig, wk_gate: &[f32], k_pre: &[f32]) {
        debug_assert_eq!(k_pre.len(), self.hkv * self.dh);
        // pending layout: [t, hkv, dh]
        self.pending.extend_from_slice(k_pre);
        self.pending_tokens += 1;
        self.len += 1;
        if self.pending_tokens == self.block_size {
            self.flush_block(cfg, wk_gate);
        }
    }

    fn flush_block(&mut self, cfg: &ModelConfig, wk_gate: &[f32]) {
        // Transpose pending [t, hkv, dh] -> [hkv, t, dh] for kcomp_entry.
        let (bs, hkv, dh, dg) = (self.block_size, self.hkv, self.dh, self.dg);
        self.block_scratch.resize(hkv * bs * dh, 0.0);
        for t in 0..bs {
            for h in 0..hkv {
                let src = (t * hkv + h) * dh;
                let dst = (h * bs + t) * dh;
                self.block_scratch[dst..dst + dh]
                    .copy_from_slice(&self.pending[src..src + dh]);
            }
        }
        let start = (self.n_complete * self.block_size) as i64;
        assert!(self.n_complete < self.cap, "kcomp entry overflow");
        gate::kcomp_entry_into(cfg, wk_gate, &self.block_scratch, bs, start,
                               &self.rope, &mut self.pooled_scratch,
                               &mut self.entry_scratch);
        // Scatter the contiguous [hkv, dg] entry into the head-major
        // store: head h's entry j lands at [(h * cap + j) * dg ..].
        let j = self.n_complete;
        for h in 0..hkv {
            let dst = (h * self.cap + j) * dg;
            simd::copy(&mut self.entries[dst..dst + dg],
                       &self.entry_scratch[h * dg..(h + 1) * dg]);
        }
        self.n_complete += 1;
        self.pending.clear();
        self.pending_tokens = 0;
    }

    /// Raw head-major entry storage `[hkv, capacity, dg]`; per head, only
    /// the leading [`n_complete`](KcompCache::n_complete) entries are
    /// valid. Pair with [`entries_stride`](KcompCache::entries_stride)
    /// for indexing (it is also exactly the `kc`/`entries_stride` layout
    /// [`gate::gate_scores`] consumes).
    pub fn entries_raw(&self) -> &[f32] {
        &self.entries
    }

    /// The per-head entry stride (capacity in entries) of
    /// [`entries_raw`](KcompCache::entries_raw).
    pub fn entries_stride(&self) -> usize {
        self.cap
    }

    /// One completed entry (`[dg]`) of head `h`.
    pub fn entry(&self, h: usize, j: usize) -> &[f32] {
        debug_assert!(j < self.n_complete);
        &self.entries[(h * self.cap + j) * self.dg..][..self.dg]
    }

    /// Copy completed entry `j` of every head into `out` (`[hkv, dg]`
    /// contiguous) — the prefix cache's export format for one gate
    /// block: one cached KV page ⇔ one kcomp entry row per head.
    pub fn export_block(&self, j: usize, out: &mut [f32]) {
        debug_assert!(j < self.n_complete);
        debug_assert_eq!(out.len(), self.hkv * self.dg);
        for h in 0..self.hkv {
            let src = (h * self.cap + j) * self.dg;
            out[h * self.dg..(h + 1) * self.dg]
                .copy_from_slice(&self.entries[src..src + self.dg]);
        }
    }

    /// Append one completed block's entry (`[hkv, dg]`, as produced by
    /// [`export_block`](KcompCache::export_block)) **without recomputing
    /// it** — a prefix-cache hit splices the shared prefix's gate blocks
    /// in and prefill resumes at the divergence block. Only legal before
    /// any partial block accumulates; advances the sequence length by one
    /// full block so the partial-block protocol stays consistent.
    pub fn adopt_block(&mut self, entry: &[f32]) {
        assert_eq!(self.pending_tokens, 0,
                   "adopt_block after partial tokens would reorder blocks");
        assert!(self.n_complete < self.cap, "kcomp entry overflow");
        debug_assert_eq!(entry.len(), self.hkv * self.dg);
        let j = self.n_complete;
        for h in 0..self.hkv {
            let dst = (h * self.cap + j) * self.dg;
            self.entries[dst..dst + self.dg]
                .copy_from_slice(&entry[h * self.dg..(h + 1) * self.dg]);
        }
        self.n_complete += 1;
        self.len += self.block_size;
    }

    /// Gate scores of `q_gate` ([hkv, dg]) against all complete entries.
    /// Returns per-head rows [hkv][n_complete].
    pub fn score(&self, cfg: &ModelConfig, q_gate: &[f32]) -> Vec<Vec<f32>> {
        debug_assert_eq!(cfg.n_kv_heads, self.hkv);
        let mut out = Vec::new();
        self.score_into(q_gate, &mut out);
        out
    }

    /// Allocation-free scoring into caller-owned rows: `out` is resized
    /// to exactly [hkv][n_complete]; row `Vec`s retain their capacity
    /// across calls, so a reused buffer stops allocating once the context
    /// reaches steady state. Values are bit-identical to [`score`].
    ///
    /// Per head, the head-major entry store makes this one contiguous
    /// multi-block sweep through the dispatched [`simd::dot_rows`]
    /// kernel (fixed 8-lane FMA reduction — SIMD and forced-scalar
    /// dispatch produce bit-identical scores).
    ///
    /// [`score`]: KcompCache::score
    pub fn score_into(&self, q_gate: &[f32], out: &mut Vec<Vec<f32>>) {
        let scale = 1.0 / (self.dg as f32).sqrt();
        crate::util::buf::resize_rows(out, self.hkv);
        for (h, row) in out.iter_mut().enumerate() {
            row.resize(self.n_complete, 0.0);
            let q = &q_gate[h * self.dg..(h + 1) * self.dg];
            let rows =
                &self.entries[h * self.cap * self.dg..][..self.n_complete * self.dg];
            simd::dot_rows(q, rows, self.dg, scale, row);
        }
    }

    /// Memory footprint in bytes of the *valid* entries (the paper's
    /// <1% claim; the head-major store is capacity-allocated but only
    /// `n_complete` entries per head hold data).
    pub fn bytes(&self) -> usize {
        self.n_complete * self.hkv * self.dg * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig {
            vocab: 4, d_model: 8, n_layers: 1, n_heads: 4, n_kv_heads: 2,
            head_dim: 4, mlp_hidden: 8, rope_theta: 10000.0, rms_eps: 1e-5,
            d_gate: 4, block_size: 4, max_seq: 64, group_size: 2,
        }
    }

    fn wk(c: &ModelConfig, rng: &mut Rng) -> Vec<f32> {
        (0..c.n_kv_heads * 3 * c.head_dim * c.d_gate)
            .map(|_| rng.normal() as f32)
            .collect()
    }

    #[test]
    fn partial_block_protocol() {
        let c = cfg();
        let mut rng = Rng::new(1);
        let w = wk(&c, &mut rng);
        let mut kc = KcompCache::new(&c, 4);
        let k: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
        assert!(!kc.has_partial());
        for t in 1..=3 {
            kc.append(&c, &w, &k);
            assert!(kc.has_partial(), "t={t}");
            assert_eq!(kc.n_complete(), 0);
            assert_eq!(kc.partial_index(), 0);
        }
        kc.append(&c, &w, &k); // completes block 0
        assert!(!kc.has_partial());
        assert_eq!(kc.n_complete(), 1);
        kc.append(&c, &w, &k);
        assert!(kc.has_partial());
        assert_eq!(kc.partial_index(), 1);
    }

    #[test]
    fn entry_matches_direct_kcomp() {
        let c = cfg();
        let mut rng = Rng::new(2);
        let w = wk(&c, &mut rng);
        let mut kc = KcompCache::new(&c, 4);
        // 8 tokens; track them to build the direct reference for block 1.
        let mut tokens: Vec<Vec<f32>> = Vec::new();
        for _ in 0..8 {
            let k: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
            kc.append(&c, &w, &k);
            tokens.push(k);
        }
        assert_eq!(kc.n_complete(), 2);
        // Reference entry for block 1 (tokens 4..8), layout [hkv, bs, dh].
        let mut block = vec![0f32; 2 * 4 * 4];
        for (t, tok) in tokens[4..8].iter().enumerate() {
            for h in 0..2 {
                let dst = (h * 4 + t) * 4;
                block[dst..dst + 4].copy_from_slice(&tok[h * 4..(h + 1) * 4]);
            }
        }
        let expect = gate::kcomp_entry(&c, &w, &block, 4, 4);
        for h in 0..2 {
            let got = kc.entry(h, 1);
            for (a, b) in got.iter().zip(&expect[h * 4..(h + 1) * 4]) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn score_shapes_and_scaling() {
        let c = cfg();
        let mut rng = Rng::new(3);
        let w = wk(&c, &mut rng);
        let mut kc = KcompCache::new(&c, 4);
        for _ in 0..12 {
            let k: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
            kc.append(&c, &w, &k);
        }
        let qg: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
        let s = kc.score(&c, &qg);
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].len(), 3);
        // Agrees with gate::gate_scores over the head-major store (the
        // entry layout and the scorer's expected layout now coincide).
        let flat = gate::gate_scores(&c, &qg, kc.entries_raw(),
                                     kc.entries_stride(), 3);
        for h in 0..2 {
            for j in 0..3 {
                assert!((s[h][j] - flat[h * 3 + j]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn score_into_matches_score_and_reuses_rows() {
        let c = cfg();
        let mut rng = Rng::new(9);
        let w = wk(&c, &mut rng);
        let mut kc = KcompCache::new(&c, 4);
        // Oversized stale buffer: must be truncated to hkv rows and the
        // surviving rows fully overwritten.
        let mut buf: Vec<Vec<f32>> = vec![vec![99.0; 7]; 5];
        for t in 0..13 {
            let k: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
            kc.append(&c, &w, &k);
            let qg: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
            kc.score_into(&qg, &mut buf);
            let expect = kc.score(&c, &qg);
            assert_eq!(buf, expect, "t={t}");
        }
    }

    #[test]
    fn adopted_blocks_are_bit_identical_to_computed_ones() {
        let c = cfg();
        let mut rng = Rng::new(11);
        let w = wk(&c, &mut rng);
        // Cold cache computes 2 blocks the normal way.
        let mut cold = KcompCache::new(&c, 4);
        let tokens: Vec<Vec<f32>> =
            (0..8).map(|_| (0..8).map(|_| rng.normal() as f32).collect()).collect();
        for k in &tokens {
            cold.append(&c, &w, k);
        }
        assert_eq!(cold.n_complete(), 2);
        // Warm cache adopts block 0's exported entry, then computes
        // block 1 itself — every entry must be bit-identical.
        let mut row = vec![0.0; c.n_kv_heads * c.d_gate];
        cold.export_block(0, &mut row);
        let mut warm = KcompCache::new(&c, 4);
        warm.adopt_block(&row);
        assert_eq!(warm.len(), 4);
        assert_eq!(warm.n_complete(), 1);
        assert!(!warm.has_partial());
        for k in &tokens[4..] {
            warm.append(&c, &w, k);
        }
        assert_eq!(warm.n_complete(), 2);
        for h in 0..c.n_kv_heads {
            for j in 0..2 {
                assert_eq!(cold.entry(h, j), warm.entry(h, j), "h={h} j={j}");
            }
        }
        // Scores over adopted entries match the cold cache's exactly.
        let qg: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
        assert_eq!(cold.score(&c, &qg), warm.score(&c, &qg));
    }

    #[test]
    fn memory_overhead_below_one_percent_at_paper_scale() {
        // Paper block 64, head_dim 128, d_gate 128: KC is 1/128 of K cache
        // (and 1/256 of KV). Our scaled shapes keep the same ratio law.
        let c = cfg();
        let mut rng = Rng::new(4);
        let w = wk(&c, &mut rng);
        let mut kc = KcompCache::new(&c, 64.min(c.max_seq));
        for _ in 0..64 {
            let k: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
            kc.append(&c, &w, &k);
        }
        let kv_bytes = 64 * c.kv_bytes_per_token_layer();
        assert!(kc.bytes() * 100 < kv_bytes, "{} vs {kv_bytes}", kc.bytes());
    }
}
