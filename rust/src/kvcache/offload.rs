//! Tiered KV storage cost model (paper §3.2: "it introduces the
//! possibility of offloading the larger KV cache to CPU or other storage
//! ... only the activated blocks need to be retrieved").
//!
//! We do not have a GPU+HBM here, so this is an *accounting* simulator: it
//! tracks which pages are resident in the fast tier (capacity-limited,
//! LRU) and charges per-byte transfer costs for misses. The ablation bench
//! compares bytes moved under dense vs. sparse selection — the paper's
//! claim is that sparse selection turns offloading from impractical
//! (every token touches everything) to practical (only the budget moves).

use std::collections::HashMap;

use super::paged::PageId;

#[derive(Debug, Clone, Copy)]
pub struct OffloadConfig {
    /// Fast-tier capacity in pages.
    pub fast_capacity: usize,
    /// Cost (simulated seconds) per byte fetched from the slow tier.
    pub fetch_s_per_byte: f64,
    /// Page size in bytes.
    pub page_bytes: usize,
}

/// LRU-managed fast tier + transfer accounting.
pub struct TieredKv {
    cfg: OffloadConfig,
    /// page -> last-touch tick
    resident: HashMap<PageId, u64>,
    tick: u64,
    pub fetches: u64,
    pub hits: u64,
    pub bytes_fetched: u64,
    pub simulated_fetch_s: f64,
}

impl TieredKv {
    pub fn new(cfg: OffloadConfig) -> TieredKv {
        TieredKv {
            cfg,
            resident: HashMap::new(),
            tick: 0,
            fetches: 0,
            hits: 0,
            bytes_fetched: 0,
            simulated_fetch_s: 0.0,
        }
    }

    /// Touch a page before attention reads it; returns the simulated
    /// fetch latency incurred (0 on hit).
    pub fn touch(&mut self, page: PageId) -> f64 {
        self.tick += 1;
        if self.resident.contains_key(&page) {
            self.hits += 1;
            self.resident.insert(page, self.tick);
            return 0.0;
        }
        // Miss: evict LRU if full, then fetch.
        if self.resident.len() >= self.cfg.fast_capacity {
            if let Some((&victim, _)) = self.resident.iter().min_by_key(|(_, &t)| t) {
                self.resident.remove(&victim);
            }
        }
        self.resident.insert(page, self.tick);
        self.fetches += 1;
        self.bytes_fetched += self.cfg.page_bytes as u64;
        let cost = self.cfg.page_bytes as f64 * self.cfg.fetch_s_per_byte;
        self.simulated_fetch_s += cost;
        cost
    }

    /// Drop a freed page from the fast tier.
    pub fn invalidate(&mut self, page: PageId) {
        self.resident.remove(&page);
    }

    pub fn resident_pages(&self) -> usize {
        self.resident.len()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.fetches;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiered(cap: usize) -> TieredKv {
        TieredKv::new(OffloadConfig {
            fast_capacity: cap,
            fetch_s_per_byte: 1e-9,
            page_bytes: 1024,
        })
    }

    #[test]
    fn hits_after_first_touch() {
        let mut t = tiered(4);
        assert!(t.touch(1) > 0.0);
        assert_eq!(t.touch(1), 0.0);
        assert_eq!(t.fetches, 1);
        assert_eq!(t.hits, 1);
    }

    #[test]
    fn lru_eviction() {
        let mut t = tiered(2);
        t.touch(1);
        t.touch(2);
        t.touch(1); // 2 is now LRU
        t.touch(3); // evicts 2
        assert_eq!(t.touch(1), 0.0, "1 stays resident");
        assert!(t.touch(2) > 0.0, "2 was evicted");
        assert!(t.resident_pages() <= 2);
    }

    #[test]
    fn capacity_never_exceeded() {
        let mut t = tiered(3);
        for p in 0..50u32 {
            t.touch(p);
            assert!(t.resident_pages() <= 3);
        }
    }

    #[test]
    fn accounting_sums() {
        let mut t = tiered(2);
        for p in [1u32, 2, 3, 1, 2, 3] {
            t.touch(p);
        }
        assert_eq!(t.bytes_fetched, t.fetches * 1024);
        assert!((t.simulated_fetch_s - t.fetches as f64 * 1024.0 * 1e-9).abs() < 1e-15);
        assert!(t.hit_rate() >= 0.0 && t.hit_rate() <= 1.0);
    }

    #[test]
    fn invalidate_forces_refetch() {
        let mut t = tiered(4);
        t.touch(7);
        t.invalidate(7);
        assert!(t.touch(7) > 0.0);
    }
}
