//! Sparsity policies (§3.1) and the selection result consumed by the
//! decode engine's gather step.

use super::topk::{merge_mandatory, threshold_into, TopkScratch};

/// How KV blocks are selected at each decode step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Full attention baseline.
    Dense,
    /// SeerAttention-R, token-budget mode: top-k gate scores, shared
    /// within each GQA group.
    GateBudget { budget_tokens: usize },
    /// SeerAttention-R, threshold mode: softmaxed gate score > t.
    GateThreshold { threshold: f32 },
    /// Adaptive sparsity via nucleus (top-p) selection on softmaxed gate
    /// scores (§6.2 future work, Twilight-style).
    GateTopP { p: f32 },
    /// Oracle selection from true attention scores (accuracy upper bound,
    /// §4.2 — "compute attention twice").
    Oracle { budget_tokens: usize },
    /// Quest baseline: per-query-head min/max upper-bound top-k.
    Quest { budget_tokens: usize },
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Dense => "dense",
            Policy::GateBudget { .. } => "seer-budget",
            Policy::GateThreshold { .. } => "seer-threshold",
            Policy::GateTopP { .. } => "seer-topp",
            Policy::Oracle { .. } => "oracle",
            Policy::Quest { .. } => "quest",
        }
    }

    pub fn is_dense(&self) -> bool {
        matches!(self, Policy::Dense)
    }

    /// Token budget -> block budget (paper: "divides the token budget by
    /// the block size").
    pub fn block_budget(budget_tokens: usize, block_size: usize) -> usize {
        (budget_tokens / block_size).max(1)
    }
}

/// Result of block selection for one sequence at one layer.
#[derive(Debug, Clone, PartialEq)]
pub enum Selection {
    /// Attend to the whole cache.
    Dense,
    /// One ascending block-index list per KV head (shared GQA sparsity).
    Shared(Vec<Vec<i32>>),
    /// One list per query head (Quest).
    PerHead(Vec<Vec<i32>>),
}

impl Selection {
    /// Max selected blocks across heads (drives the gather staging size).
    pub fn max_blocks(&self) -> usize {
        match self {
            Selection::Dense => 0,
            Selection::Shared(v) | Selection::PerHead(v) => {
                v.iter().map(|x| x.len()).max().unwrap_or(0)
            }
        }
    }

    /// Total selected blocks summed over heads (sparsity accounting).
    pub fn total_blocks(&self) -> usize {
        match self {
            Selection::Dense => 0,
            Selection::Shared(v) | Selection::PerHead(v) => {
                v.iter().map(|x| x.len()).sum()
            }
        }
    }
}

/// Discriminant of a [`SelectionBuf`] — mirrors [`Selection`] without
/// owning row storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SelKind {
    #[default]
    Dense,
    Shared,
    PerHead,
}

/// Reusable per-slot selection storage. The decode engine keeps one per
/// batch slot: row `Vec`s retain their capacity across steps and layers,
/// so steady-state selection performs no heap allocation, and the gather
/// stage borrows rows as `&[i32]` instead of cloning a [`Selection`].
#[derive(Debug, Clone, Default)]
pub struct SelectionBuf {
    kind: SelKind,
    rows: Vec<Vec<i32>>,
    n_rows: usize,
}

impl SelectionBuf {
    pub fn new() -> SelectionBuf {
        SelectionBuf::default()
    }

    pub fn kind(&self) -> SelKind {
        self.kind
    }

    /// Mark this slot dense (no rows).
    pub fn set_dense(&mut self) {
        self.kind = SelKind::Dense;
        self.n_rows = 0;
    }

    /// Start a Shared/PerHead selection with `n_rows` cleared rows.
    pub fn begin(&mut self, kind: SelKind, n_rows: usize) {
        debug_assert_ne!(kind, SelKind::Dense, "use set_dense()");
        self.kind = kind;
        if self.rows.len() < n_rows {
            self.rows.resize_with(n_rows, Vec::new);
        }
        for row in &mut self.rows[..n_rows] {
            row.clear();
        }
        self.n_rows = n_rows;
    }

    /// Active rows (ascending block indices each).
    pub fn rows(&self) -> &[Vec<i32>] {
        &self.rows[..self.n_rows]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut Vec<i32> {
        debug_assert!(r < self.n_rows);
        &mut self.rows[r]
    }

    /// Max selected blocks across rows (drives the staging variant).
    pub fn max_blocks(&self) -> usize {
        self.rows().iter().map(|x| x.len()).max().unwrap_or(0)
    }

    /// Materialise as an owning [`Selection`] (diagnostics / tests).
    pub fn as_selection(&self) -> Selection {
        match self.kind {
            SelKind::Dense => Selection::Dense,
            SelKind::Shared => Selection::Shared(self.rows().to_vec()),
            SelKind::PerHead => Selection::PerHead(self.rows().to_vec()),
        }
    }
}

/// Budget selection over per-head score rows (`scores[h]` has one entry
/// per *complete* block). The partial-block index (if any) is always
/// force-included (§3.2: "the last block is always activated").
pub fn select_budget(scores: &[Vec<f32>], block_budget: usize,
                     partial_block: Option<i32>) -> Vec<Vec<i32>> {
    let mut buf = SelectionBuf::new();
    select_budget_into(scores, block_budget, partial_block,
                       &mut TopkScratch::new(), &mut buf);
    buf.rows().to_vec()
}

/// Allocation-free budget selection into a reused [`SelectionBuf`].
pub fn select_budget_into(scores: &[Vec<f32>], block_budget: usize,
                          partial_block: Option<i32>, topk: &mut TopkScratch,
                          out: &mut SelectionBuf) {
    out.begin(SelKind::Shared, scores.len());
    // Reserve one slot for the mandatory partial block.
    let k = if partial_block.is_some() {
        block_budget.saturating_sub(1)
    } else {
        block_budget
    };
    for (h, row) in scores.iter().enumerate() {
        let sel = out.row_mut(h);
        topk.topk_into(row, k, sel);
        if let Some(p) = partial_block {
            merge_mandatory(sel, p);
        }
    }
}

/// Top-p selection over per-head softmaxed score rows.
pub fn select_top_p(probs: &[Vec<f32>], p: f32,
                    partial_block: Option<i32>) -> Vec<Vec<i32>> {
    let mut buf = SelectionBuf::new();
    select_top_p_into(probs, p, partial_block, &mut TopkScratch::new(), &mut buf);
    buf.rows().to_vec()
}

/// Allocation-free top-p selection into a reused [`SelectionBuf`].
pub fn select_top_p_into(probs: &[Vec<f32>], p: f32,
                         partial_block: Option<i32>, topk: &mut TopkScratch,
                         out: &mut SelectionBuf) {
    out.begin(SelKind::Shared, probs.len());
    for (h, row) in probs.iter().enumerate() {
        let sel = out.row_mut(h);
        topk.top_p_into(row, p, sel);
        if let Some(pb) = partial_block {
            merge_mandatory(sel, pb);
        }
    }
}

/// Threshold selection over per-head softmaxed score rows.
pub fn select_threshold(probs: &[Vec<f32>], threshold: f32,
                        partial_block: Option<i32>) -> Vec<Vec<i32>> {
    let mut buf = SelectionBuf::new();
    select_threshold_into(probs, threshold, partial_block, &mut buf);
    buf.rows().to_vec()
}

/// Allocation-free threshold selection into a reused [`SelectionBuf`].
pub fn select_threshold_into(probs: &[Vec<f32>], threshold: f32,
                             partial_block: Option<i32>, out: &mut SelectionBuf) {
    out.begin(SelKind::Shared, probs.len());
    for (h, row) in probs.iter().enumerate() {
        let sel = out.row_mut(h);
        threshold_into(row, threshold, sel);
        if let Some(p) = partial_block {
            merge_mandatory(sel, p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_budget_floor_and_min() {
        assert_eq!(Policy::block_budget(64, 16), 4);
        assert_eq!(Policy::block_budget(65, 16), 4);
        assert_eq!(Policy::block_budget(4, 16), 1);
    }

    #[test]
    fn budget_reserves_slot_for_partial() {
        let scores = vec![vec![0.9, 0.1, 0.8, 0.2]];
        // budget 2 with a partial block at 4: one top-k slot + the partial.
        let sel = select_budget(&scores, 2, Some(4));
        assert_eq!(sel[0], vec![0, 4]);
        // without partial: two top-k slots.
        let sel = select_budget(&scores, 2, None);
        assert_eq!(sel[0], vec![0, 2]);
    }

    #[test]
    fn budget_never_exceeds_budget() {
        let scores = vec![vec![0.5; 10], vec![0.1; 10]];
        for b in 1..6 {
            for partial in [None, Some(10)] {
                let sel = select_budget(&scores, b, partial);
                for row in &sel {
                    assert!(row.len() <= b.max(1), "b={b} row={row:?}");
                }
            }
        }
    }

    #[test]
    fn threshold_includes_partial_even_below() {
        let probs = vec![vec![0.001, 0.9]];
        let sel = select_threshold(&probs, 0.5, Some(2));
        assert_eq!(sel[0], vec![1, 2]);
    }

    #[test]
    fn selection_accounting() {
        let s = Selection::Shared(vec![vec![0, 1], vec![2]]);
        assert_eq!(s.max_blocks(), 2);
        assert_eq!(s.total_blocks(), 3);
        assert_eq!(Selection::Dense.max_blocks(), 0);
    }

    #[test]
    fn per_head_differs_when_scores_differ() {
        let scores = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let sel = select_budget(&scores, 1, None);
        assert_eq!(sel, vec![vec![0], vec![1]]);
    }

    #[test]
    fn reused_buf_matches_fresh_selection() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(21);
        let mut buf = SelectionBuf::new();
        let mut topk = TopkScratch::new();
        for step in 0..40 {
            let heads = rng.range(1, 5);
            let n = rng.range(1, 24);
            let scores: Vec<Vec<f32>> = (0..heads)
                .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
                .collect();
            let partial = if rng.bool(0.5) { Some(n as i32) } else { None };
            let b = rng.range(1, 8);
            select_budget_into(&scores, b, partial, &mut topk, &mut buf);
            assert_eq!(buf.kind(), SelKind::Shared);
            assert_eq!(buf.rows(), &select_budget(&scores, b, partial)[..],
                       "budget step={step}");
            let t = rng.f32();
            select_threshold_into(&scores, t, partial, &mut buf);
            assert_eq!(buf.rows(), &select_threshold(&scores, t, partial)[..]);
            let p = rng.f32();
            select_top_p_into(&scores, p, partial, &mut topk, &mut buf);
            assert_eq!(buf.rows(), &select_top_p(&scores, p, partial)[..]);
        }
    }

    #[test]
    fn selection_buf_shrinks_and_converts() {
        let mut buf = SelectionBuf::new();
        buf.begin(SelKind::PerHead, 4);
        for r in 0..4 {
            buf.row_mut(r).extend_from_slice(&[r as i32]);
        }
        assert_eq!(buf.max_blocks(), 1);
        assert_eq!(buf.as_selection(),
                   Selection::PerHead(vec![vec![0], vec![1], vec![2], vec![3]]));
        // Fewer rows next step: stale rows must not leak into view.
        buf.begin(SelKind::Shared, 2);
        assert_eq!(buf.rows(), &[Vec::<i32>::new(), Vec::new()][..]);
        buf.set_dense();
        assert_eq!(buf.as_selection(), Selection::Dense);
        assert_eq!(buf.max_blocks(), 0);
    }
}
