//! Sparsity policies (§3.1) and the selection result consumed by the
//! decode engine's gather step.

use super::topk::{merge_mandatory, threshold_indices, top_p_indices, topk_indices};

/// How KV blocks are selected at each decode step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Full attention baseline.
    Dense,
    /// SeerAttention-R, token-budget mode: top-k gate scores, shared
    /// within each GQA group.
    GateBudget { budget_tokens: usize },
    /// SeerAttention-R, threshold mode: softmaxed gate score > t.
    GateThreshold { threshold: f32 },
    /// Adaptive sparsity via nucleus (top-p) selection on softmaxed gate
    /// scores (§6.2 future work, Twilight-style).
    GateTopP { p: f32 },
    /// Oracle selection from true attention scores (accuracy upper bound,
    /// §4.2 — "compute attention twice").
    Oracle { budget_tokens: usize },
    /// Quest baseline: per-query-head min/max upper-bound top-k.
    Quest { budget_tokens: usize },
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Dense => "dense",
            Policy::GateBudget { .. } => "seer-budget",
            Policy::GateThreshold { .. } => "seer-threshold",
            Policy::GateTopP { .. } => "seer-topp",
            Policy::Oracle { .. } => "oracle",
            Policy::Quest { .. } => "quest",
        }
    }

    pub fn is_dense(&self) -> bool {
        matches!(self, Policy::Dense)
    }

    /// Token budget -> block budget (paper: "divides the token budget by
    /// the block size").
    pub fn block_budget(budget_tokens: usize, block_size: usize) -> usize {
        (budget_tokens / block_size).max(1)
    }
}

/// Result of block selection for one sequence at one layer.
#[derive(Debug, Clone, PartialEq)]
pub enum Selection {
    /// Attend to the whole cache.
    Dense,
    /// One ascending block-index list per KV head (shared GQA sparsity).
    Shared(Vec<Vec<i32>>),
    /// One list per query head (Quest).
    PerHead(Vec<Vec<i32>>),
}

impl Selection {
    /// Max selected blocks across heads (drives the gather staging size).
    pub fn max_blocks(&self) -> usize {
        match self {
            Selection::Dense => 0,
            Selection::Shared(v) | Selection::PerHead(v) => {
                v.iter().map(|x| x.len()).max().unwrap_or(0)
            }
        }
    }

    /// Total selected blocks summed over heads (sparsity accounting).
    pub fn total_blocks(&self) -> usize {
        match self {
            Selection::Dense => 0,
            Selection::Shared(v) | Selection::PerHead(v) => {
                v.iter().map(|x| x.len()).sum()
            }
        }
    }
}

/// Budget selection over per-head score rows (`scores[h]` has one entry
/// per *complete* block). The partial-block index (if any) is always
/// force-included (§3.2: "the last block is always activated").
pub fn select_budget(scores: &[Vec<f32>], block_budget: usize,
                     partial_block: Option<i32>) -> Vec<Vec<i32>> {
    scores
        .iter()
        .map(|row| {
            // Reserve one slot for the mandatory partial block.
            let k = if partial_block.is_some() {
                block_budget.saturating_sub(1)
            } else {
                block_budget
            };
            let mut sel = topk_indices(row, k);
            if let Some(p) = partial_block {
                merge_mandatory(&mut sel, p);
            }
            sel
        })
        .collect()
}

/// Top-p selection over per-head softmaxed score rows.
pub fn select_top_p(probs: &[Vec<f32>], p: f32,
                    partial_block: Option<i32>) -> Vec<Vec<i32>> {
    probs
        .iter()
        .map(|row| {
            let mut sel = top_p_indices(row, p);
            if let Some(pb) = partial_block {
                merge_mandatory(&mut sel, pb);
            }
            sel
        })
        .collect()
}

/// Threshold selection over per-head softmaxed score rows.
pub fn select_threshold(probs: &[Vec<f32>], threshold: f32,
                        partial_block: Option<i32>) -> Vec<Vec<i32>> {
    probs
        .iter()
        .map(|row| {
            let mut sel = threshold_indices(row, threshold);
            if let Some(p) = partial_block {
                merge_mandatory(&mut sel, p);
            }
            sel
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_budget_floor_and_min() {
        assert_eq!(Policy::block_budget(64, 16), 4);
        assert_eq!(Policy::block_budget(65, 16), 4);
        assert_eq!(Policy::block_budget(4, 16), 1);
    }

    #[test]
    fn budget_reserves_slot_for_partial() {
        let scores = vec![vec![0.9, 0.1, 0.8, 0.2]];
        // budget 2 with a partial block at 4: one top-k slot + the partial.
        let sel = select_budget(&scores, 2, Some(4));
        assert_eq!(sel[0], vec![0, 4]);
        // without partial: two top-k slots.
        let sel = select_budget(&scores, 2, None);
        assert_eq!(sel[0], vec![0, 2]);
    }

    #[test]
    fn budget_never_exceeds_budget() {
        let scores = vec![vec![0.5; 10], vec![0.1; 10]];
        for b in 1..6 {
            for partial in [None, Some(10)] {
                let sel = select_budget(&scores, b, partial);
                for row in &sel {
                    assert!(row.len() <= b.max(1), "b={b} row={row:?}");
                }
            }
        }
    }

    #[test]
    fn threshold_includes_partial_even_below() {
        let probs = vec![vec![0.001, 0.9]];
        let sel = select_threshold(&probs, 0.5, Some(2));
        assert_eq!(sel[0], vec![1, 2]);
    }

    #[test]
    fn selection_accounting() {
        let s = Selection::Shared(vec![vec![0, 1], vec![2]]);
        assert_eq!(s.max_blocks(), 2);
        assert_eq!(s.total_blocks(), 3);
        assert_eq!(Selection::Dense.max_blocks(), 0);
    }

    #[test]
    fn per_head_differs_when_scores_differ() {
        let scores = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let sel = select_budget(&scores, 1, None);
        assert_eq!(sel, vec![vec![0], vec![1]]);
    }
}
