//! Top-k / threshold block selection over gate scores.

/// Indices of the `k` largest scores (ties broken toward lower index),
/// returned in ascending index order. O(n log n) on a scratch sort —
/// n is blocks-per-context (tens), so this is never hot enough to need a
/// partial select.
pub fn topk_indices(scores: &[f32], k: usize) -> Vec<i32> {
    let k = k.min(scores.len());
    if k == 0 {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut picked: Vec<i32> = order[..k].iter().map(|&i| i as i32).collect();
    picked.sort_unstable();
    picked
}

/// Indices with score > threshold, ascending. The paper's threshold mode
/// (§3.1) applies this to the softmaxed gate scores.
pub fn threshold_indices(scores: &[f32], threshold: f32) -> Vec<i32> {
    scores
        .iter()
        .enumerate()
        .filter(|(_, s)| **s > threshold)
        .map(|(i, _)| i as i32)
        .collect()
}

/// Merge a mandatory block index into a selection (keeps ascending order,
/// no duplicate). Used for the always-active partial last block (§3.2).
pub fn merge_mandatory(sel: &mut Vec<i32>, idx: i32) {
    match sel.binary_search(&idx) {
        Ok(_) => {}
        Err(pos) => sel.insert(pos, idx),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn topk_matches_full_sort() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let n = rng.range(1, 40);
            let k = rng.range(0, n + 1);
            let scores: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let got = topk_indices(&scores, k);
            // Reference: sort all, take top k values (multiset compare).
            let mut vals: Vec<f32> = scores.clone();
            vals.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let mut got_vals: Vec<f32> = got.iter().map(|&i| scores[i as usize]).collect();
            got_vals.sort_by(|a, b| b.partial_cmp(a).unwrap());
            assert_eq!(got_vals.len(), k.min(n));
            for (a, b) in got_vals.iter().zip(vals.iter()) {
                assert_eq!(a, b);
            }
            // Ascending, unique.
            assert!(got.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn topk_k_larger_than_n() {
        assert_eq!(topk_indices(&[3.0, 1.0], 10), vec![0, 1]);
    }

    #[test]
    fn threshold_selects_strictly_above() {
        let s = [0.1, 0.5, 0.5001, 0.9];
        assert_eq!(threshold_indices(&s, 0.5), vec![2, 3]);
        assert_eq!(threshold_indices(&s, 1.0), Vec::<i32>::new());
    }

    #[test]
    fn merge_mandatory_no_dup_keeps_order() {
        let mut v = vec![1, 4, 7];
        merge_mandatory(&mut v, 4);
        assert_eq!(v, vec![1, 4, 7]);
        merge_mandatory(&mut v, 0);
        assert_eq!(v, vec![0, 1, 4, 7]);
        merge_mandatory(&mut v, 9);
        assert_eq!(v, vec![0, 1, 4, 7, 9]);
    }
}

/// Top-p (nucleus) block selection over *softmaxed* gate scores — the
/// paper's §6.2 future-work direction (explored by Twilight/MagicPIG):
/// pick the smallest set of blocks whose probability mass reaches `p`,
/// adapting the sparsity ratio per head and per step. Returns ascending
/// indices; always selects at least one block.
pub fn top_p_indices(probs: &[f32], p: f32) -> Vec<i32> {
    if probs.is_empty() {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..probs.len()).collect();
    order.sort_by(|&a, &b| {
        probs[b]
            .partial_cmp(&probs[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut mass = 0.0f32;
    let mut picked: Vec<i32> = Vec::new();
    for &i in &order {
        picked.push(i as i32);
        mass += probs[i];
        if mass >= p {
            break;
        }
    }
    picked.sort_unstable();
    picked
}

#[cfg(test)]
mod top_p_tests {
    use super::*;

    #[test]
    fn selects_minimal_prefix_of_mass() {
        let probs = [0.5, 0.3, 0.15, 0.05];
        assert_eq!(top_p_indices(&probs, 0.5), vec![0]);
        assert_eq!(top_p_indices(&probs, 0.75), vec![0, 1]);
        assert_eq!(top_p_indices(&probs, 0.9), vec![0, 1, 2]);
        assert_eq!(top_p_indices(&probs, 1.0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn adapts_to_concentration() {
        // Peaked distribution -> tiny selection; flat -> large.
        let peaked = [0.97, 0.01, 0.01, 0.01];
        let flat = [0.25, 0.25, 0.25, 0.25];
        assert_eq!(top_p_indices(&peaked, 0.9).len(), 1);
        assert_eq!(top_p_indices(&flat, 0.9).len(), 4);
    }

    #[test]
    fn always_at_least_one() {
        assert_eq!(top_p_indices(&[0.4, 0.6], 0.0), vec![1]);
        assert!(top_p_indices(&[], 0.9).is_empty());
    }
}
