//! Top-k / threshold block selection over gate scores.
//!
//! The decode hot path runs a selection per slot, per layer, per head at
//! every token, so these are written to be allocation-free in steady
//! state: [`TopkScratch`] owns a reusable index buffer and partitions it
//! with `select_nth_unstable_by` (O(n) expected) instead of sorting the
//! whole score row. The `Vec`-returning functions are thin wrappers kept
//! for tests and callers off the hot path.

use std::cmp::Ordering;

/// Reusable scratch for partial top-k selection. One instance per
/// selecting thread; the internal index buffer grows to the largest score
/// row seen and is then reused allocation-free.
#[derive(Debug, Default, Clone)]
pub struct TopkScratch {
    order: Vec<u32>,
}

/// Comparator: score descending, index ascending on ties — a total order
/// (absent NaNs), which makes the partial-select prefix identical to the
/// full-sort prefix.
#[inline]
fn by_score_desc(scores: &[f32], a: &u32, b: &u32) -> Ordering {
    scores[*b as usize]
        .partial_cmp(&scores[*a as usize])
        .unwrap_or(Ordering::Equal)
        .then(a.cmp(b))
}

impl TopkScratch {
    pub fn new() -> TopkScratch {
        TopkScratch::default()
    }

    fn fill_order(&mut self, n: usize) -> &mut [u32] {
        self.order.clear();
        self.order.extend(0..n as u32);
        &mut self.order[..]
    }

    /// Indices of the `k` largest scores (ties broken toward lower
    /// index), written to `out` in ascending index order. Produces
    /// exactly what the seed's full-sort `topk_indices` produced, via an
    /// O(n + k log k) partial selection.
    pub fn topk_into(&mut self, scores: &[f32], k: usize, out: &mut Vec<i32>) {
        out.clear();
        let n = scores.len();
        let k = k.min(n);
        if k == 0 {
            return;
        }
        let order = self.fill_order(n);
        if k < n {
            order.select_nth_unstable_by(k - 1, |a, b| by_score_desc(scores, a, b));
        }
        out.extend(order[..k].iter().map(|&i| i as i32));
        out.sort_unstable();
    }

    /// Top-p (nucleus) selection over *softmaxed* scores: the smallest
    /// set of blocks whose probability mass reaches `p` (at least one
    /// block), ascending indices. Identical output to a full descending
    /// sort + prefix scan; implemented as a doubling partial selection so
    /// peaked distributions never sort the whole row.
    pub fn top_p_into(&mut self, probs: &[f32], p: f32, out: &mut Vec<i32>) {
        out.clear();
        let n = probs.len();
        if n == 0 {
            return;
        }
        let mut k = 4.min(n);
        loop {
            let order = self.fill_order(n);
            if k < n {
                order.select_nth_unstable_by(k - 1, |a, b| by_score_desc(probs, a, b));
            }
            // The candidate prefix in exact descending-prob order (same
            // order the reference accumulates in, so the f32 mass sum is
            // bit-identical).
            order[..k].sort_unstable_by(|a, b| by_score_desc(probs, a, b));
            let mut mass = 0.0f32;
            let mut taken = 0usize;
            for &i in order[..k].iter() {
                taken += 1;
                mass += probs[i as usize];
                if mass >= p {
                    break;
                }
            }
            if mass >= p || k == n {
                out.extend(order[..taken].iter().map(|&i| i as i32));
                out.sort_unstable();
                return;
            }
            k = (k * 2).min(n);
        }
    }
}

/// Indices of the `k` largest scores (ties broken toward lower index),
/// returned in ascending index order.
pub fn topk_indices(scores: &[f32], k: usize) -> Vec<i32> {
    let mut out = Vec::new();
    TopkScratch::new().topk_into(scores, k, &mut out);
    out
}

/// Indices with score > threshold, ascending. The paper's threshold mode
/// (§3.1) applies this to the softmaxed gate scores.
pub fn threshold_indices(scores: &[f32], threshold: f32) -> Vec<i32> {
    let mut out = Vec::new();
    threshold_into(scores, threshold, &mut out);
    out
}

/// Allocation-free variant of [`threshold_indices`].
pub fn threshold_into(scores: &[f32], threshold: f32, out: &mut Vec<i32>) {
    out.clear();
    out.extend(
        scores
            .iter()
            .enumerate()
            .filter(|(_, s)| **s > threshold)
            .map(|(i, _)| i as i32),
    );
}

/// Top-p (nucleus) block selection over *softmaxed* gate scores — the
/// paper's §6.2 future-work direction (explored by Twilight/MagicPIG):
/// pick the smallest set of blocks whose probability mass reaches `p`,
/// adapting the sparsity ratio per head and per step. Returns ascending
/// indices; always selects at least one block.
pub fn top_p_indices(probs: &[f32], p: f32) -> Vec<i32> {
    let mut out = Vec::new();
    TopkScratch::new().top_p_into(probs, p, &mut out);
    out
}

/// Merge a mandatory block index into a selection (keeps ascending order,
/// no duplicate). Used for the always-active partial last block (§3.2).
pub fn merge_mandatory(sel: &mut Vec<i32>, idx: i32) {
    match sel.binary_search(&idx) {
        Ok(_) => {}
        Err(pos) => sel.insert(pos, idx),
    }
}

/// How many entries of `sel` appear in the *ascending-sorted* `oracle`
/// row. O(k log k) via binary search — replaces the engine's old
/// O(k²) `contains` scan in recall accounting.
pub fn count_hits_sorted(sel: &[i32], oracle: &[i32]) -> usize {
    debug_assert!(oracle.windows(2).all(|w| w[0] < w[1]));
    sel.iter().filter(|x| oracle.binary_search(x).is_ok()).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn topk_matches_full_sort() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let n = rng.range(1, 40);
            let k = rng.range(0, n + 1);
            let scores: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let got = topk_indices(&scores, k);
            // Reference: sort all, take top k values (multiset compare).
            let mut vals: Vec<f32> = scores.clone();
            vals.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let mut got_vals: Vec<f32> = got.iter().map(|&i| scores[i as usize]).collect();
            got_vals.sort_by(|a, b| b.partial_cmp(a).unwrap());
            assert_eq!(got_vals.len(), k.min(n));
            for (a, b) in got_vals.iter().zip(vals.iter()) {
                assert_eq!(a, b);
            }
            // Ascending, unique.
            assert!(got.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn topk_k_larger_than_n() {
        assert_eq!(topk_indices(&[3.0, 1.0], 10), vec![0, 1]);
    }

    #[test]
    fn topk_ties_break_toward_lower_index() {
        // All-equal scores: partial selection must still pick the lowest
        // indices, exactly like the seed's stable tie-break.
        assert_eq!(topk_indices(&[1.0; 8], 3), vec![0, 1, 2]);
        assert_eq!(topk_indices(&[2.0, 1.0, 2.0, 2.0, 1.0], 3), vec![0, 2, 3]);
    }

    #[test]
    fn scratch_reuse_matches_fresh() {
        let mut rng = Rng::new(12);
        let mut scratch = TopkScratch::new();
        let mut out = Vec::new();
        for _ in 0..50 {
            let n = rng.range(1, 64);
            let k = rng.range(0, n + 2);
            let scores: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            scratch.topk_into(&scores, k, &mut out);
            assert_eq!(out, topk_indices(&scores, k));
        }
    }

    #[test]
    fn threshold_selects_strictly_above() {
        let s = [0.1, 0.5, 0.5001, 0.9];
        assert_eq!(threshold_indices(&s, 0.5), vec![2, 3]);
        assert_eq!(threshold_indices(&s, 1.0), Vec::<i32>::new());
    }

    #[test]
    fn merge_mandatory_no_dup_keeps_order() {
        let mut v = vec![1, 4, 7];
        merge_mandatory(&mut v, 4);
        assert_eq!(v, vec![1, 4, 7]);
        merge_mandatory(&mut v, 0);
        assert_eq!(v, vec![0, 1, 4, 7]);
        merge_mandatory(&mut v, 9);
        assert_eq!(v, vec![0, 1, 4, 7, 9]);
    }

    #[test]
    fn count_hits_sorted_matches_contains() {
        let mut rng = Rng::new(13);
        for _ in 0..100 {
            let n = rng.range(1, 30);
            let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let k = rng.range(0, n + 1);
            let oracle: Vec<i32> = topk_indices(&scores, k);
            let m = rng.range(0, 12);
            let sel: Vec<i32> = (0..m).map(|_| rng.below(n) as i32).collect();
            let slow = sel.iter().filter(|x| oracle.contains(x)).count();
            assert_eq!(count_hits_sorted(&sel, &oracle), slow);
        }
    }
}

#[cfg(test)]
mod top_p_tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn selects_minimal_prefix_of_mass() {
        let probs = [0.5, 0.3, 0.15, 0.05];
        assert_eq!(top_p_indices(&probs, 0.5), vec![0]);
        assert_eq!(top_p_indices(&probs, 0.75), vec![0, 1]);
        assert_eq!(top_p_indices(&probs, 0.9), vec![0, 1, 2]);
        assert_eq!(top_p_indices(&probs, 1.0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn adapts_to_concentration() {
        // Peaked distribution -> tiny selection; flat -> large.
        let peaked = [0.97, 0.01, 0.01, 0.01];
        let flat = [0.25, 0.25, 0.25, 0.25];
        assert_eq!(top_p_indices(&peaked, 0.9).len(), 1);
        assert_eq!(top_p_indices(&flat, 0.9).len(), 4);
    }

    #[test]
    fn always_at_least_one() {
        assert_eq!(top_p_indices(&[0.4, 0.6], 0.0), vec![1]);
        assert!(top_p_indices(&[], 0.9).is_empty());
    }

    #[test]
    fn doubling_matches_full_sort_reference() {
        // Reference: the seed's full-sort implementation.
        fn reference(probs: &[f32], p: f32) -> Vec<i32> {
            if probs.is_empty() {
                return Vec::new();
            }
            let mut order: Vec<usize> = (0..probs.len()).collect();
            order.sort_by(|&a, &b| {
                probs[b]
                    .partial_cmp(&probs[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            let mut mass = 0.0f32;
            let mut picked: Vec<i32> = Vec::new();
            for &i in &order {
                picked.push(i as i32);
                mass += probs[i];
                if mass >= p {
                    break;
                }
            }
            picked.sort_unstable();
            picked
        }
        let mut rng = Rng::new(14);
        for _ in 0..100 {
            let n = rng.range(1, 48);
            let mut probs: Vec<f32> = (0..n).map(|_| rng.f32() + 1e-6).collect();
            let total: f32 = probs.iter().sum();
            for x in &mut probs {
                *x /= total;
            }
            let p = rng.f32();
            assert_eq!(top_p_indices(&probs, p), reference(&probs, p), "n={n} p={p}");
        }
    }
}
