//! Sparse block selection: the policies (§3.1), top-k / threshold
//! utilities, and the Quest training-free baseline.

pub mod policy;
pub mod quest;
pub mod topk;

pub use policy::{Policy, SelKind, Selection, SelectionBuf};
pub use topk::TopkScratch;
