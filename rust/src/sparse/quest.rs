//! Quest baseline (Tang et al. 2024): training-free, query-aware KV block
//! selection. Per block, keep elementwise min/max of the RoPE'd keys; at
//! decode time, score each block with the upper bound
//! `ub(q, block) = sum_d max(q_d * min_d, q_d * max_d)`,
//! which upper-bounds q·k for every key in the block. Selection is
//! per-*query*-head (Quest does not share sparsity in a GQA group —
//! paper §4.1 / Fig 7 note), and the paper's comparison configuration
//! uses the same block size as SeerAttention-R with sparse attention in
//! all layers.

use crate::model::ModelConfig;
use crate::util::simd;

/// Incrementally-maintained per-block min/max key metadata for one layer
/// of one sequence. Layout: per kv head, per block, min[dh] ++ max[dh].
#[derive(Debug, Clone)]
pub struct QuestMeta {
    hkv: usize,
    dh: usize,
    block_size: usize,
    max_blocks: usize,
    /// [hkv, max_blocks, 2, dh]
    data: Vec<f32>,
    len: usize,
}

impl QuestMeta {
    pub fn new(cfg: &ModelConfig, block_size: usize, max_seq: usize) -> QuestMeta {
        let max_blocks = max_seq.div_ceil(block_size);
        QuestMeta {
            hkv: cfg.n_kv_heads,
            dh: cfg.head_dim,
            block_size,
            max_blocks,
            data: vec![0.0; cfg.n_kv_heads * max_blocks * 2 * cfg.head_dim],
            len: 0,
        }
    }

    /// Append one token's RoPE'd keys (`k_rope`: [hkv, dh]) at position
    /// `self.len`.
    pub fn append(&mut self, k_rope: &[f32]) {
        debug_assert_eq!(k_rope.len(), self.hkv * self.dh);
        let blk = self.len / self.block_size;
        assert!(blk < self.max_blocks, "quest metadata overflow");
        let fresh = self.len % self.block_size == 0;
        for h in 0..self.hkv {
            let base = ((h * self.max_blocks + blk) * 2) * self.dh;
            let krow = &k_rope[h * self.dh..(h + 1) * self.dh];
            for d in 0..self.dh {
                let (mn, mx) = (base + d, base + self.dh + d);
                if fresh {
                    self.data[mn] = krow[d];
                    self.data[mx] = krow[d];
                } else {
                    self.data[mn] = self.data[mn].min(krow[d]);
                    self.data[mx] = self.data[mx].max(krow[d]);
                }
            }
        }
        self.len += 1;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    /// Copy block `blk`'s metadata for every head into `out`
    /// (`[hkv, 2, dh]` contiguous: per head `min[dh] ++ max[dh]`) — the
    /// prefix cache's export format for one Quest block.
    pub fn export_block(&self, blk: usize, out: &mut [f32]) {
        debug_assert!(blk * self.block_size < self.len);
        debug_assert_eq!(out.len(), self.hkv * 2 * self.dh);
        for h in 0..self.hkv {
            let base = ((h * self.max_blocks + blk) * 2) * self.dh;
            out[h * 2 * self.dh..(h + 1) * 2 * self.dh]
                .copy_from_slice(&self.data[base..base + 2 * self.dh]);
        }
    }

    /// Append one *full* block's metadata (`[hkv, 2, dh]`, as produced by
    /// [`export_block`](QuestMeta::export_block)) without replaying its
    /// tokens — the prefix-cache splice for a shared-prefix block.
    /// Only legal at a block boundary; advances `len` by one full block.
    pub fn adopt_block(&mut self, meta: &[f32]) {
        assert_eq!(self.len % self.block_size, 0,
                   "adopt_block mid-block would corrupt min/max state");
        debug_assert_eq!(meta.len(), self.hkv * 2 * self.dh);
        let blk = self.len / self.block_size;
        assert!(blk < self.max_blocks, "quest metadata overflow");
        for h in 0..self.hkv {
            let base = ((h * self.max_blocks + blk) * 2) * self.dh;
            self.data[base..base + 2 * self.dh]
                .copy_from_slice(&meta[h * 2 * self.dh..(h + 1) * 2 * self.dh]);
        }
        self.len += self.block_size;
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of blocks with at least one token.
    pub fn n_blocks(&self) -> usize {
        self.len.div_ceil(self.block_size)
    }

    /// Upper-bound scores for one *query head*'s query vector against
    /// every (partially) filled block of its kv head.
    pub fn scores(&self, kv_head: usize, q: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.scores_into(kv_head, q, &mut out);
        out
    }

    /// Allocation-free variant of [`scores`]: resizes `out` to the block
    /// count and overwrites every entry, so a reused buffer stops
    /// allocating once the context stops growing. Each block's
    /// `Σ_d max(q·min, q·max)` bound runs through the dispatched
    /// [`simd::quest_ub`] kernel (fixed 8-lane reduction on every
    /// target, so SIMD and forced-scalar dispatch agree bitwise).
    ///
    /// [`scores`]: QuestMeta::scores
    pub fn scores_into(&self, kv_head: usize, q: &[f32], out: &mut Vec<f32>) {
        debug_assert_eq!(q.len(), self.dh);
        let nblk = self.n_blocks();
        out.clear();
        out.resize(nblk, 0.0);
        for (blk, o) in out.iter_mut().enumerate() {
            // Per-block metadata is `min[dh] ++ max[dh]` — exactly the
            // kernel's operand layout.
            let base = ((kv_head * self.max_blocks + blk) * 2) * self.dh;
            *o = simd::quest_ub(q, &self.data[base..base + 2 * self.dh]);
        }
    }

    /// The provable invariant: ub >= q·k for every cached key in the
    /// block. Exposed for the property tests.
    pub fn upper_bounds_hold(&self, kv_head: usize, q: &[f32], keys: &[Vec<f32>]) -> bool {
        let scores = self.scores(kv_head, q);
        for (t, k) in keys.iter().enumerate().take(self.len) {
            let dot: f32 = q.iter().zip(k).map(|(a, b)| a * b).sum();
            if dot > scores[t / self.block_size] + 1e-4 {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig {
            vocab: 4, d_model: 8, n_layers: 1, n_heads: 4, n_kv_heads: 2,
            head_dim: 8, mlp_hidden: 8, rope_theta: 10000.0, rms_eps: 1e-5,
            d_gate: 4, block_size: 4, max_seq: 64, group_size: 2,
        }
    }

    #[test]
    fn minmax_tracks_extremes() {
        let c = cfg();
        let mut m = QuestMeta::new(&c, 4, 64);
        // Two tokens into block 0, head 0 dim 0 values 1.0 then -3.0.
        let mut k = vec![0f32; c.n_kv_heads * c.head_dim];
        k[0] = 1.0;
        m.append(&k);
        k[0] = -3.0;
        m.append(&k);
        let mut q = vec![0f32; c.head_dim];
        q[0] = 1.0;
        assert!((m.scores(0, &q)[0] - 1.0).abs() < 1e-6); // q*max wins
        q[0] = -1.0;
        assert!((m.scores(0, &q)[0] - 3.0).abs() < 1e-6); // q*min wins
    }

    #[test]
    fn property_upper_bound_dominates_true_dot() {
        let c = cfg();
        let mut rng = Rng::new(99);
        for _ in 0..30 {
            let mut m = QuestMeta::new(&c, 4, 64);
            let n = rng.range(1, 40);
            let mut keys_h0: Vec<Vec<f32>> = Vec::new();
            for _ in 0..n {
                let k: Vec<f32> = (0..c.n_kv_heads * c.head_dim)
                    .map(|_| rng.normal() as f32)
                    .collect();
                keys_h0.push(k[..c.head_dim].to_vec());
                m.append(&k);
            }
            let q: Vec<f32> = (0..c.head_dim).map(|_| rng.normal() as f32).collect();
            assert!(m.upper_bounds_hold(0, &q, &keys_h0));
        }
    }

    #[test]
    fn block_boundaries_reset() {
        let c = cfg();
        let mut m = QuestMeta::new(&c, 4, 64);
        let mut k = vec![0f32; c.n_kv_heads * c.head_dim];
        for t in 0..8 {
            k[0] = if t < 4 { 100.0 } else { -1.0 };
            m.append(&k);
        }
        let mut q = vec![0f32; c.head_dim];
        q[0] = 1.0;
        let s = m.scores(0, &q);
        assert_eq!(s.len(), 2);
        assert!((s[0] - 100.0).abs() < 1e-5);
        assert!((s[1] + 1.0).abs() < 1e-5, "block 1 must not inherit block 0 max");
    }

    #[test]
    fn scores_into_matches_scores() {
        let c = cfg();
        let mut rng = Rng::new(31);
        let mut m = QuestMeta::new(&c, 4, 64);
        let mut buf = vec![5.0f32; 3]; // stale content must be overwritten
        for _ in 0..11 {
            let k: Vec<f32> = (0..c.n_kv_heads * c.head_dim)
                .map(|_| rng.normal() as f32)
                .collect();
            m.append(&k);
            let q: Vec<f32> = (0..c.head_dim).map(|_| rng.normal() as f32).collect();
            for h in 0..c.n_kv_heads {
                m.scores_into(h, &q, &mut buf);
                assert_eq!(buf, m.scores(h, &q));
            }
        }
    }

    #[test]
    fn adopted_block_scores_bit_identical() {
        let c = cfg();
        let mut rng = Rng::new(77);
        let mut cold = QuestMeta::new(&c, 4, 64);
        let tokens: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..c.n_kv_heads * c.head_dim).map(|_| rng.normal() as f32).collect())
            .collect();
        for k in &tokens {
            cold.append(k);
        }
        // Warm meta adopts block 0, replays only block 1.
        let mut row = vec![0.0; c.n_kv_heads * 2 * c.head_dim];
        cold.export_block(0, &mut row);
        let mut warm = QuestMeta::new(&c, 4, 64);
        warm.adopt_block(&row);
        assert_eq!(warm.len(), 4);
        for k in &tokens[4..] {
            warm.append(k);
        }
        let q: Vec<f32> = (0..c.head_dim).map(|_| rng.normal() as f32).collect();
        for h in 0..c.n_kv_heads {
            assert_eq!(cold.scores(h, &q), warm.scores(h, &q), "h={h}");
        }
    }

    #[test]
    fn partial_block_counted() {
        let c = cfg();
        let mut m = QuestMeta::new(&c, 4, 64);
        let k = vec![1f32; c.n_kv_heads * c.head_dim];
        for _ in 0..5 {
            m.append(&k);
        }
        assert_eq!(m.n_blocks(), 2);
    }
}
