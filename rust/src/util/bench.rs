//! Tiny benchmark harness (criterion is not in the offline vendor set).
//!
//! Measures wall-clock of a closure with warmup, reports median +
//! mean ± std over iterations. Used by `rust/benches/*` (harness = false)
//! and the Fig 6 kernel-speedup runner.

use std::time::Instant;

use crate::util::stats::Series;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub std_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.3} ms (median, n={}, mean {:.3} ± {:.3})",
            self.name,
            self.median_s * 1e3,
            self.iters,
            self.mean_s * 1e3,
            self.std_s * 1e3
        )
    }
}

/// Run `f` with `warmup` untimed calls and at least `min_iters` timed calls
/// (stops early after `budget_s` seconds of measurement).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize, budget_s: f64,
                         mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut series = Series::new();
    let start = Instant::now();
    let mut iters = 0;
    while iters < min_iters || (start.elapsed().as_secs_f64() < budget_s && iters < 10_000) {
        let t = Instant::now();
        f();
        series.push(t.elapsed().as_secs_f64());
        iters += 1;
    }
    BenchResult {
        name: name.to_string(),
        iters,
        median_s: series.median(),
        mean_s: series.mean(),
        std_s: series.std(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("noop-ish", 1, 5, 0.01, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.median_s >= 0.0);
        assert!(r.report().contains("noop-ish"));
    }
}
