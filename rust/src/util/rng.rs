//! Deterministic PRNG (splitmix64 seeding a xoshiro256**).
//!
//! Used for workload generation, sampling, and the hand-rolled property
//! tests. Everything experiment-related takes an explicit seed so runs
//! are reproducible.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let mut s = [0u64; 4];
        for x in &mut s {
            *x = splitmix64(&mut st);
        }
        Rng { s }
    }

    /// Derive an independent stream (for parallel / nested generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate lambda (for Poisson arrival gaps).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct elements from 0..n (k <= n).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut pool: Vec<usize> = (0..n).collect();
        self.shuffle(&mut pool);
        pool.truncate(k);
        pool
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let x = r.range(5, 9);
            assert!((5..9).contains(&x));
        }
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(4);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Rng::new(8);
        let s = r.sample_distinct(20, 10);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 10);
    }
}
