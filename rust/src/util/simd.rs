//! Runtime-dispatched SIMD kernels for the decode hot path.
//!
//! Every inner loop the coordinator runs per decode token — gate dot
//! products (`KcompCache::score_into`, `gate::gate_scores`), Quest
//! min/max upper bounds, softmax rows, RoPE rotation, and the staged
//! gather copies — funnels through this module. Dispatch is resolved at
//! runtime: AVX2+FMA via `std::arch` on x86_64 (checked once with
//! `is_x86_feature_detected!`), NEON on aarch64, and a scalar fallback
//! everywhere else.
//!
//! ## Determinism contract
//!
//! All dispatch targets produce **bit-identical** results. The scalar
//! fallback is not a naive sequential loop — it emulates the exact
//! 8-lane reduction the vector paths perform:
//!
//! - Reductions (dot, sum, max, Quest upper bound) accumulate into 8
//!   fixed lanes (`lanes[l]` holds elements `≡ l (mod 8)`), tail
//!   elements fold into lanes `0..tail`, and the final horizontal
//!   reduction is the fixed tree [`hsum8`]/[`hmax8`] — the vector paths
//!   store their accumulator lanes and run the *same* scalar tree.
//! - Fused multiply-adds use `f32::mul_add` in the scalar path and the
//!   hardware FMA in the vector paths — both correctly rounded, so
//!   identical. Plain mul/add kernels (`axpy`, `quest_ub`, `rope_rotate`)
//!   use unfused mul+add on every target.
//! - `max` uses select semantics `a > b ? a : b` on every target
//!   (matching x86 `maxps`; NEON emulates it with compare+select), so
//!   even the `±0.0` tie cases agree bitwise.
//! - Elementwise kernels (scale, axpy, rotate, copy, fill) are trivially
//!   order-independent.
//!
//! The serving consequence: `--no-simd` (or `SEERATTN_SIMD=scalar`) and
//! auto-dispatch produce identical scores, selections, and served
//! tokens — asserted end-to-end by `rust/tests/simd_parity.rs` and the
//! `decode_hot_path` bench.
//!
//! ## Forcing the scalar path
//!
//! Dispatch honours, in order: the `SEERATTN_SIMD=scalar` environment
//! variable (read once per process — CI pins the forced-scalar job with
//! it), then the process-wide [`set_scalar`] flag (the CLI `--no-simd`
//! flag and `EngineConfig::simd = false` set it). Every kernel is
//! allocation-free (fixed stack arrays only), preserving the hot path's
//! zero-steady-state-allocation invariant.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Fixed logical lane count of the reduction contract (one AVX2 vector;
/// two NEON quads; eight scalar accumulators).
pub const LANES: usize = 8;

/// Resolved dispatch target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// 8-lane emulation in scalar code (bit-identical to the vector
    /// paths by construction).
    Scalar,
    /// x86_64 AVX2 + FMA.
    Avx2Fma,
    /// aarch64 NEON (two 4-lane quads emulate the 8-lane contract).
    Neon,
}

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

fn env_scalar() -> bool {
    static ENV: OnceLock<bool> = OnceLock::new();
    *ENV.get_or_init(|| std::env::var("SEERATTN_SIMD").as_deref() == Ok("scalar"))
}

/// Force (or un-force) the scalar path process-wide. The
/// `SEERATTN_SIMD=scalar` environment variable cannot be un-forced.
pub fn set_scalar(force: bool) {
    FORCE_SCALAR.store(force, Ordering::SeqCst);
}

/// Whether dispatch is currently pinned to the scalar path.
pub fn scalar_forced() -> bool {
    env_scalar() || FORCE_SCALAR.load(Ordering::Relaxed)
}

fn detect() -> Target {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return Target::Avx2Fma;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Target::Neon;
        }
    }
    Target::Scalar
}

/// The hardware's best target (cached detection; ignores forcing).
pub fn detected() -> Target {
    static DETECTED: OnceLock<Target> = OnceLock::new();
    *DETECTED.get_or_init(detect)
}

/// The target kernels dispatch to right now (detection + forcing).
pub fn target() -> Target {
    if scalar_forced() {
        Target::Scalar
    } else {
        detected()
    }
}

impl Target {
    /// Stable wire name (bench provenance / metrics reporting).
    pub fn name(self) -> &'static str {
        match self {
            Target::Scalar => "scalar",
            Target::Avx2Fma => "avx2+fma",
            Target::Neon => "neon",
        }
    }
}

/// Stable wire name of the active target (bench/metrics reporting).
pub fn target_name() -> &'static str {
    target().name()
}

/// Raw CPU feature detection, for bench provenance
/// (`BENCH_decode.json`'s `config.simd` block).
#[derive(Debug, Clone, Copy, Default)]
pub struct CpuFeatures {
    pub avx2: bool,
    pub fma: bool,
    pub neon: bool,
}

pub fn cpu_features() -> CpuFeatures {
    #[allow(unused_mut)]
    let mut f = CpuFeatures::default();
    #[cfg(target_arch = "x86_64")]
    {
        f.avx2 = std::arch::is_x86_feature_detected!("avx2");
        f.fma = std::arch::is_x86_feature_detected!("fma");
    }
    #[cfg(target_arch = "aarch64")]
    {
        f.neon = std::arch::is_aarch64_feature_detected!("neon");
    }
    f
}

// ---------------------------------------------------------------------
// Shared fixed-order reduction helpers (every target funnels its 8
// accumulator lanes through these, which is what makes the targets
// bit-identical).
// ---------------------------------------------------------------------

/// Fixed horizontal-sum tree over the 8 lanes.
#[inline]
fn hsum8(l: [f32; LANES]) -> f32 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// Fixed horizontal-max tree over the 8 lanes (select semantics).
#[inline]
fn hmax8(l: [f32; LANES]) -> f32 {
    sel_max(
        sel_max(sel_max(l[0], l[1]), sel_max(l[2], l[3])),
        sel_max(sel_max(l[4], l[5]), sel_max(l[6], l[7])),
    )
}

/// `a > b ? a : b` — the exact semantics of x86 `maxps(a, b)` (returns
/// `b` on ties and NaN), emulated on every target.
#[inline]
fn sel_max(a: f32, b: f32) -> f32 {
    if a > b {
        a
    } else {
        b
    }
}

macro_rules! dispatch {
    ($name:ident ( $($arg:expr),* )) => {
        match target() {
            #[cfg(target_arch = "x86_64")]
            Target::Avx2Fma => unsafe { x86::$name($($arg),*) },
            #[cfg(target_arch = "aarch64")]
            Target::Neon => unsafe { neon::$name($($arg),*) },
            _ => scalar::$name($($arg),*),
        }
    };
}

// ---------------------------------------------------------------------
// Public kernels.
// ---------------------------------------------------------------------

/// Dot product with the fixed 8-lane FMA reduction.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    dispatch!(dot(a, b))
}

/// `out[j] = dot(q, rows[j*d..][..d]) * scale` over `out.len()`
/// contiguous rows — the gate-scoring multi-block sweep. Bit-identical
/// to calling [`dot`] per row.
pub fn dot_rows(q: &[f32], rows: &[f32], d: usize, scale: f32, out: &mut [f32]) {
    debug_assert_eq!(q.len(), d);
    debug_assert!(rows.len() >= out.len() * d);
    dispatch!(dot_rows(q, rows, d, scale, out))
}

/// Sum with the fixed 8-lane reduction.
pub fn sum(x: &[f32]) -> f32 {
    dispatch!(sum(x))
}

/// Max with the fixed 8-lane select-max reduction
/// (`f32::NEG_INFINITY` for an empty slice).
pub fn max(x: &[f32]) -> f32 {
    dispatch!(max(x))
}

/// In-place `x[i] *= s` (elementwise; identical on every target).
pub fn scale(x: &mut [f32], s: f32) {
    dispatch!(scale(x, s))
}

/// In-place `out[i] += a * x[i]` with *unfused* mul+add on every target
/// (matches the pre-SIMD K-compression projection exactly).
pub fn axpy(out: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(out.len(), x.len());
    dispatch!(axpy(out, x, a))
}

/// Quest block upper bound `Σ_d max(q_d·min_d, q_d·max_d)` over a
/// `[min(d), max(d)]` metadata block (`minmax.len() == 2 * q.len()`),
/// with the fixed 8-lane reduction.
pub fn quest_ub(q: &[f32], minmax: &[f32]) -> f32 {
    debug_assert_eq!(minmax.len(), 2 * q.len());
    dispatch!(quest_ub(q, minmax))
}

/// In-place interleaved-pair RoPE rotation of one even-length row from
/// precomputed patterns: `cos2 = [c0,c0,c1,c1,..]`,
/// `nsin2 = [-s0,s0,-s1,s1,..]`. Computes
/// `row[2i] = e·c + o·(−s)` and `row[2i+1] = o·c + e·s` with unfused
/// mul+add — bitwise equal to the reference `e·c − o·s` / `e·s + o·c`
/// (IEEE: `x + (−y) ≡ x − y`, and addition is commutative bitwise).
pub fn rope_rotate(row: &mut [f32], cos2: &[f32], nsin2: &[f32]) {
    debug_assert_eq!(row.len() % 2, 0);
    debug_assert_eq!(row.len(), cos2.len());
    debug_assert_eq!(row.len(), nsin2.len());
    dispatch!(rope_rotate(row, cos2, nsin2))
}

/// The gather stage's block copy, routed through the kernel layer for
/// uniformity but resolved to `copy_from_slice` (= `memcpy`) on every
/// target: memcpy is already alignment-aware, unrolled vector code and
/// a copy is bit-identical by definition, so dispatching here would
/// only add a branch to the bandwidth-bound stage.
pub fn copy(dst: &mut [f32], src: &[f32]) {
    dst.copy_from_slice(src);
}

/// Mask fill; same reasoning as [`copy`] — `fill` (= `memset`-class
/// splat) on every target.
pub fn fill(dst: &mut [f32], v: f32) {
    dst.fill(v);
}

/// In-place softmax of one row: 8-lane max, scalar `exp` (elementwise —
/// identical on every target), 8-lane sum, vectorized normalize.
pub fn softmax_row(row: &mut [f32]) {
    if row.is_empty() {
        return;
    }
    let m = max(row);
    for x in row.iter_mut() {
        *x = (*x - m).exp();
    }
    let s = sum(row);
    let inv = 1.0 / s.max(1e-30);
    scale(row, inv);
}

// ---------------------------------------------------------------------
// Scalar fallback: 8-lane emulation, bit-identical to the vector paths.
// ---------------------------------------------------------------------

mod scalar {
    use super::{hmax8, hsum8, sel_max, LANES};

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / LANES;
        let mut lanes = [0f32; LANES];
        for c in 0..chunks {
            let o = c * LANES;
            for (l, lane) in lanes.iter_mut().enumerate() {
                *lane = a[o + l].mul_add(b[o + l], *lane);
            }
        }
        for (l, t) in (chunks * LANES..n).enumerate() {
            lanes[l] = a[t].mul_add(b[t], lanes[l]);
        }
        hsum8(lanes)
    }

    pub fn dot_rows(q: &[f32], rows: &[f32], d: usize, scale: f32,
                    out: &mut [f32]) {
        for (j, o) in out.iter_mut().enumerate() {
            *o = dot(q, &rows[j * d..(j + 1) * d]) * scale;
        }
    }

    pub fn sum(x: &[f32]) -> f32 {
        let n = x.len();
        let chunks = n / LANES;
        let mut lanes = [0f32; LANES];
        for c in 0..chunks {
            let o = c * LANES;
            for (l, lane) in lanes.iter_mut().enumerate() {
                *lane += x[o + l];
            }
        }
        for (l, t) in (chunks * LANES..n).enumerate() {
            lanes[l] += x[t];
        }
        hsum8(lanes)
    }

    pub fn max(x: &[f32]) -> f32 {
        let n = x.len();
        let chunks = n / LANES;
        let mut lanes = [f32::NEG_INFINITY; LANES];
        for c in 0..chunks {
            let o = c * LANES;
            for (l, lane) in lanes.iter_mut().enumerate() {
                *lane = sel_max(*lane, x[o + l]);
            }
        }
        for (l, t) in (chunks * LANES..n).enumerate() {
            lanes[l] = sel_max(lanes[l], x[t]);
        }
        hmax8(lanes)
    }

    pub fn scale(x: &mut [f32], s: f32) {
        for v in x.iter_mut() {
            *v *= s;
        }
    }

    pub fn axpy(out: &mut [f32], x: &[f32], a: f32) {
        for (o, xv) in out.iter_mut().zip(x) {
            *o += a * *xv;
        }
    }

    pub fn quest_ub(q: &[f32], minmax: &[f32]) -> f32 {
        let d = q.len();
        let (mn, mx) = minmax.split_at(d);
        let chunks = d / LANES;
        let mut lanes = [0f32; LANES];
        for c in 0..chunks {
            let o = c * LANES;
            for (l, lane) in lanes.iter_mut().enumerate() {
                let j = o + l;
                *lane += sel_max(q[j] * mn[j], q[j] * mx[j]);
            }
        }
        for (l, t) in (chunks * LANES..d).enumerate() {
            lanes[l] += sel_max(q[t] * mn[t], q[t] * mx[t]);
        }
        hsum8(lanes)
    }

    /// Rotate an even-length run of interleaved pairs (also the vector
    /// paths' tail handler, so tails are identical by construction).
    pub fn rope_rotate(row: &mut [f32], cos2: &[f32], nsin2: &[f32]) {
        for i in 0..row.len() / 2 {
            let (e, o) = (row[2 * i], row[2 * i + 1]);
            row[2 * i] = e * cos2[2 * i] + o * nsin2[2 * i];
            row[2 * i + 1] = o * cos2[2 * i + 1] + e * nsin2[2 * i + 1];
        }
    }

}

// ---------------------------------------------------------------------
// x86_64: AVX2 + FMA.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    use super::{hmax8, hsum8, sel_max, LANES};

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / LANES;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let o = c * LANES;
            acc = _mm256_fmadd_ps(_mm256_loadu_ps(a.as_ptr().add(o)),
                                  _mm256_loadu_ps(b.as_ptr().add(o)), acc);
        }
        let mut lanes = [0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for (l, t) in (chunks * LANES..n).enumerate() {
            lanes[l] = a[t].mul_add(b[t], lanes[l]);
        }
        hsum8(lanes)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_rows(q: &[f32], rows: &[f32], d: usize, scale: f32,
                           out: &mut [f32]) {
        for (j, o) in out.iter_mut().enumerate() {
            *o = dot(q, &rows[j * d..(j + 1) * d]) * scale;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn sum(x: &[f32]) -> f32 {
        let n = x.len();
        let chunks = n / LANES;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(x.as_ptr().add(c * LANES)));
        }
        let mut lanes = [0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for (l, t) in (chunks * LANES..n).enumerate() {
            lanes[l] += x[t];
        }
        hsum8(lanes)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn max(x: &[f32]) -> f32 {
        let n = x.len();
        let chunks = n / LANES;
        let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
        for c in 0..chunks {
            // maxps(acc, v) = acc > v ? acc : v — sel_max semantics.
            acc = _mm256_max_ps(acc, _mm256_loadu_ps(x.as_ptr().add(c * LANES)));
        }
        let mut lanes = [0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for (l, t) in (chunks * LANES..n).enumerate() {
            lanes[l] = sel_max(lanes[l], x[t]);
        }
        hmax8(lanes)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn scale(x: &mut [f32], s: f32) {
        let n = x.len();
        let chunks = n / LANES;
        let vs = _mm256_set1_ps(s);
        for c in 0..chunks {
            let p = x.as_mut_ptr().add(c * LANES);
            _mm256_storeu_ps(p, _mm256_mul_ps(_mm256_loadu_ps(p), vs));
        }
        for v in &mut x[chunks * LANES..] {
            *v *= s;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(out: &mut [f32], x: &[f32], a: f32) {
        let n = out.len();
        let chunks = n / LANES;
        let va = _mm256_set1_ps(a);
        for c in 0..chunks {
            let o = c * LANES;
            let p = out.as_mut_ptr().add(o);
            // Unfused mul + add, matching the scalar `*o += a * x`.
            let prod = _mm256_mul_ps(va, _mm256_loadu_ps(x.as_ptr().add(o)));
            _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), prod));
        }
        for (o, xv) in out[chunks * LANES..].iter_mut().zip(&x[chunks * LANES..]) {
            *o += a * *xv;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn quest_ub(q: &[f32], minmax: &[f32]) -> f32 {
        let d = q.len();
        let (mn, mx) = minmax.split_at(d);
        let chunks = d / LANES;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let o = c * LANES;
            let vq = _mm256_loadu_ps(q.as_ptr().add(o));
            let a = _mm256_mul_ps(vq, _mm256_loadu_ps(mn.as_ptr().add(o)));
            let b = _mm256_mul_ps(vq, _mm256_loadu_ps(mx.as_ptr().add(o)));
            acc = _mm256_add_ps(acc, _mm256_max_ps(a, b));
        }
        let mut lanes = [0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for (l, t) in (chunks * LANES..d).enumerate() {
            lanes[l] += sel_max(q[t] * mn[t], q[t] * mx[t]);
        }
        hsum8(lanes)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn rope_rotate(row: &mut [f32], cos2: &[f32], nsin2: &[f32]) {
        let n = row.len();
        let chunks = n / LANES;
        for c in 0..chunks {
            let o = c * LANES;
            let p = row.as_mut_ptr().add(o);
            let v = _mm256_loadu_ps(p);
            // Swap each interleaved (even, odd) pair: [1,0,3,2] per lane.
            let sw = _mm256_permute_ps::<0b1011_0001>(v);
            let t1 = _mm256_mul_ps(v, _mm256_loadu_ps(cos2.as_ptr().add(o)));
            let t2 = _mm256_mul_ps(sw, _mm256_loadu_ps(nsin2.as_ptr().add(o)));
            _mm256_storeu_ps(p, _mm256_add_ps(t1, t2));
        }
        let o = chunks * LANES;
        super::scalar::rope_rotate(&mut row[o..], &cos2[o..], &nsin2[o..]);
    }

}

// ---------------------------------------------------------------------
// aarch64: NEON (two 4-lane quads = the 8-lane contract).
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    use super::{hmax8, hsum8, sel_max, LANES};

    /// `a > b ? a : b` per lane — emulates x86 `maxps` exactly (NEON's
    /// own `vmaxq_f32` differs on NaN propagation).
    #[inline]
    unsafe fn vmax_sel(a: float32x4_t, b: float32x4_t) -> float32x4_t {
        vbslq_f32(vcgtq_f32(a, b), a, b)
    }

    #[inline]
    unsafe fn store8(lanes: &mut [f32; LANES], lo: float32x4_t, hi: float32x4_t) {
        vst1q_f32(lanes.as_mut_ptr(), lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), hi);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / LANES;
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        for c in 0..chunks {
            let o = c * LANES;
            acc0 = vfmaq_f32(acc0, vld1q_f32(a.as_ptr().add(o)),
                             vld1q_f32(b.as_ptr().add(o)));
            acc1 = vfmaq_f32(acc1, vld1q_f32(a.as_ptr().add(o + 4)),
                             vld1q_f32(b.as_ptr().add(o + 4)));
        }
        let mut lanes = [0f32; LANES];
        store8(&mut lanes, acc0, acc1);
        for (l, t) in (chunks * LANES..n).enumerate() {
            lanes[l] = a[t].mul_add(b[t], lanes[l]);
        }
        hsum8(lanes)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot_rows(q: &[f32], rows: &[f32], d: usize, scale: f32,
                           out: &mut [f32]) {
        for (j, o) in out.iter_mut().enumerate() {
            *o = dot(q, &rows[j * d..(j + 1) * d]) * scale;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn sum(x: &[f32]) -> f32 {
        let n = x.len();
        let chunks = n / LANES;
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        for c in 0..chunks {
            let o = c * LANES;
            acc0 = vaddq_f32(acc0, vld1q_f32(x.as_ptr().add(o)));
            acc1 = vaddq_f32(acc1, vld1q_f32(x.as_ptr().add(o + 4)));
        }
        let mut lanes = [0f32; LANES];
        store8(&mut lanes, acc0, acc1);
        for (l, t) in (chunks * LANES..n).enumerate() {
            lanes[l] += x[t];
        }
        hsum8(lanes)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn max(x: &[f32]) -> f32 {
        let n = x.len();
        let chunks = n / LANES;
        let mut acc0 = vdupq_n_f32(f32::NEG_INFINITY);
        let mut acc1 = vdupq_n_f32(f32::NEG_INFINITY);
        for c in 0..chunks {
            let o = c * LANES;
            acc0 = vmax_sel(acc0, vld1q_f32(x.as_ptr().add(o)));
            acc1 = vmax_sel(acc1, vld1q_f32(x.as_ptr().add(o + 4)));
        }
        let mut lanes = [f32::NEG_INFINITY; LANES];
        store8(&mut lanes, acc0, acc1);
        for (l, t) in (chunks * LANES..n).enumerate() {
            lanes[l] = sel_max(lanes[l], x[t]);
        }
        hmax8(lanes)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn scale(x: &mut [f32], s: f32) {
        let n = x.len();
        let chunks = n / LANES;
        let vs = vdupq_n_f32(s);
        for c in 0..chunks {
            let o = c * LANES;
            let p = x.as_mut_ptr().add(o);
            vst1q_f32(p, vmulq_f32(vld1q_f32(p), vs));
            let p4 = p.add(4);
            vst1q_f32(p4, vmulq_f32(vld1q_f32(p4), vs));
        }
        for v in &mut x[chunks * LANES..] {
            *v *= s;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(out: &mut [f32], x: &[f32], a: f32) {
        let n = out.len();
        let chunks = n / LANES;
        let va = vdupq_n_f32(a);
        for c in 0..chunks {
            let o = c * LANES;
            let p = out.as_mut_ptr().add(o);
            let prod = vmulq_f32(va, vld1q_f32(x.as_ptr().add(o)));
            vst1q_f32(p, vaddq_f32(vld1q_f32(p), prod));
            let p4 = p.add(4);
            let prod4 = vmulq_f32(va, vld1q_f32(x.as_ptr().add(o + 4)));
            vst1q_f32(p4, vaddq_f32(vld1q_f32(p4), prod4));
        }
        for (o, xv) in out[chunks * LANES..].iter_mut().zip(&x[chunks * LANES..]) {
            *o += a * *xv;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn quest_ub(q: &[f32], minmax: &[f32]) -> f32 {
        let d = q.len();
        let (mn, mx) = minmax.split_at(d);
        let chunks = d / LANES;
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        for c in 0..chunks {
            let o = c * LANES;
            let vq0 = vld1q_f32(q.as_ptr().add(o));
            let a0 = vmulq_f32(vq0, vld1q_f32(mn.as_ptr().add(o)));
            let b0 = vmulq_f32(vq0, vld1q_f32(mx.as_ptr().add(o)));
            acc0 = vaddq_f32(acc0, vmax_sel(a0, b0));
            let vq1 = vld1q_f32(q.as_ptr().add(o + 4));
            let a1 = vmulq_f32(vq1, vld1q_f32(mn.as_ptr().add(o + 4)));
            let b1 = vmulq_f32(vq1, vld1q_f32(mx.as_ptr().add(o + 4)));
            acc1 = vaddq_f32(acc1, vmax_sel(a1, b1));
        }
        let mut lanes = [0f32; LANES];
        store8(&mut lanes, acc0, acc1);
        for (l, t) in (chunks * LANES..d).enumerate() {
            lanes[l] += sel_max(q[t] * mn[t], q[t] * mx[t]);
        }
        hsum8(lanes)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn rope_rotate(row: &mut [f32], cos2: &[f32], nsin2: &[f32]) {
        let n = row.len();
        let quads = n / 4;
        for c in 0..quads {
            let o = c * 4;
            let p = row.as_mut_ptr().add(o);
            let v = vld1q_f32(p);
            // Swap each interleaved (even, odd) pair within the quad.
            let sw = vrev64q_f32(v);
            let t1 = vmulq_f32(v, vld1q_f32(cos2.as_ptr().add(o)));
            let t2 = vmulq_f32(sw, vld1q_f32(nsin2.as_ptr().add(o)));
            vst1q_f32(p, vaddq_f32(t1, t2));
        }
        let o = quads * 4;
        super::scalar::rope_rotate(&mut row[o..], &cos2[o..], &nsin2[o..]);
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Serializes the tests that read or write the process-global
    /// dispatch flag: without it, `force_scalar_flag_pins_target`
    /// toggling scalar mid-run would silently turn the vector-vs-scalar
    /// comparisons below into scalar-vs-scalar (vacuously green).
    static MODE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn mode_lock() -> std::sync::MutexGuard<'static, ()> {
        MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn vecs(rng: &mut Rng, n: usize) -> (Vec<f32>, Vec<f32>) {
        ((0..n).map(|_| rng.normal() as f32).collect(),
         (0..n).map(|_| rng.normal() as f32).collect())
    }

    #[test]
    fn scalar_dot_close_to_naive() {
        let mut rng = Rng::new(1);
        for n in [0usize, 1, 5, 8, 9, 16, 17, 100] {
            let (a, b) = vecs(&mut rng, n);
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            let got = scalar::dot(&a, &b) as f64;
            assert!((got - naive).abs() <= 1e-4 * (1.0 + naive.abs()),
                    "n={n}: {got} vs {naive}");
        }
    }

    #[test]
    fn active_target_matches_scalar_emulation_bitwise() {
        // On AVX2/NEON hardware this compares vector vs scalar; on other
        // machines it is a self-check. The cross-mode dispatch tests live
        // in rust/tests/simd_parity.rs (they toggle the global flag).
        let _g = mode_lock();
        let mut rng = Rng::new(2);
        for n in 0..=2 * LANES + 3 {
            let (a, b) = vecs(&mut rng, n);
            assert_eq!(dot(&a, &b).to_bits(), scalar::dot(&a, &b).to_bits(), "dot n={n}");
            assert_eq!(sum(&a).to_bits(), scalar::sum(&a).to_bits(), "sum n={n}");
            assert_eq!(max(&a).to_bits(), scalar::max(&a).to_bits(), "max n={n}");
            let (q, _) = vecs(&mut rng, n);
            let mm: Vec<f32> = {
                let (lo, hi) = vecs(&mut rng, n);
                let mut m = Vec::new();
                // min row then max row (values need not be ordered for
                // the kernel arithmetic itself).
                m.extend_from_slice(&lo);
                m.extend_from_slice(&hi);
                m
            };
            assert_eq!(quest_ub(&q, &mm).to_bits(),
                       scalar::quest_ub(&q, &mm).to_bits(), "quest n={n}");
            let mut x1 = a.clone();
            let mut x2 = a.clone();
            scale(&mut x1, 1.7);
            scalar::scale(&mut x2, 1.7);
            assert_eq!(x1, x2, "scale n={n}");
            let mut o1 = b.clone();
            let mut o2 = b.clone();
            axpy(&mut o1, &a, -0.3);
            scalar::axpy(&mut o2, &a, -0.3);
            assert_eq!(o1, o2, "axpy n={n}");
            let mut c1 = vec![9.0; n];
            copy(&mut c1, &a);
            assert_eq!(c1, a, "copy n={n}");
            fill(&mut c1, 3.25);
            assert!(c1.iter().all(|&x| x == 3.25), "fill n={n}");
        }
        // RoPE: even lengths only.
        for half in 0..=LANES + 2 {
            let n = 2 * half;
            let (mut r1, _) = vecs(&mut rng, n);
            let mut r2 = r1.clone();
            let (c2v, s2v) = vecs(&mut rng, n);
            rope_rotate(&mut r1, &c2v, &s2v);
            scalar::rope_rotate(&mut r2, &c2v, &s2v);
            assert_eq!(r1, r2, "rope n={n}");
        }
    }

    #[test]
    fn dot_rows_matches_per_row_dot() {
        let _g = mode_lock();
        let mut rng = Rng::new(3);
        for d in [1usize, 3, 8, 13, 32] {
            let (q, _) = vecs(&mut rng, d);
            let (rows, _) = vecs(&mut rng, 5 * d);
            let mut out = vec![0f32; 5];
            dot_rows(&q, &rows, d, 0.5, &mut out);
            for j in 0..5 {
                let want = dot(&q, &rows[j * d..(j + 1) * d]) * 0.5;
                assert_eq!(out[j].to_bits(), want.to_bits(), "d={d} j={j}");
            }
        }
    }

    #[test]
    fn softmax_row_sums_to_one_and_orders() {
        let mut row = vec![1.0f32, 2.0, 3.0, -1.0, 0.5];
        softmax_row(&mut row);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(row[2] > row[1] && row[1] > row[0]);
        let mut empty: Vec<f32> = Vec::new();
        softmax_row(&mut empty); // no panic
    }

    #[test]
    fn max_of_empty_is_neg_infinity() {
        assert_eq!(max(&[]), f32::NEG_INFINITY);
    }

    #[test]
    fn force_scalar_flag_pins_target() {
        let _g = mode_lock();
        set_scalar(true);
        assert_eq!(target(), Target::Scalar);
        assert_eq!(target_name(), "scalar");
        set_scalar(false);
        if std::env::var("SEERATTN_SIMD").as_deref() == Ok("scalar") {
            // Env override (the CI forced-scalar job) cannot be un-forced.
            assert_eq!(target(), Target::Scalar);
        } else {
            assert_eq!(target(), detected(),
                       "set_scalar(false) must un-pin dispatch");
        }
    }

    #[test]
    fn detection_is_consistent() {
        let f = cpu_features();
        match detected() {
            Target::Avx2Fma => assert!(f.avx2 && f.fma),
            Target::Neon => assert!(f.neon),
            Target::Scalar => {}
        }
    }
}
