//! Latency / throughput statistics: online summaries and percentile
//! estimation for the serving metrics and the benchmark harness.

/// A recording of raw samples with summary statistics.
#[derive(Debug, Clone, Default)]
pub struct Series {
    samples: Vec<f64>,
}

impl Series {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    /// Raw samples, in insertion order (merging goes through
    /// [`Series::extend_from`]; this is the read-side accessor for
    /// callers that need the underlying data, e.g. tests / exporters).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Append every sample of `other` (per-shard -> fleet merging).
    pub fn extend_from(&mut self, other: &Series) {
        self.samples.extend_from_slice(&other.samples);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }

    /// Percentile via linear interpolation on the sorted samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            let w = rank - lo as f64;
            s[lo] * (1.0 - w) + s[hi] * w
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn summary(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.3}{u} p50={:.3}{u} p95={:.3}{u} max={:.3}{u}",
            self.len(),
            self.mean(),
            self.median(),
            self.percentile(95.0),
            self.max(),
            u = unit
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_series_is_safe() {
        let s = Series::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
    }

    #[test]
    fn mean_and_std() {
        let mut s = Series::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138).abs() < 1e-3);
    }

    #[test]
    fn percentiles() {
        let mut s = Series::new();
        for x in 1..=100 {
            s.push(x as f64);
        }
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(95.0) - 95.05).abs() < 1e-9);
    }

    #[test]
    fn extend_from_merges_samples() {
        let mut a = Series::new();
        let mut b = Series::new();
        for x in [1.0, 2.0] {
            a.push(x);
        }
        for x in [3.0, 4.0] {
            b.push(x);
        }
        a.extend_from(&b);
        assert_eq!(a.len(), 4);
        assert_eq!(a.samples(), &[1.0, 2.0, 3.0, 4.0]);
        assert!((a.mean() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn min_max() {
        let mut s = Series::new();
        for x in [3.0, -1.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.min(), -1.0);
        assert_eq!(s.max(), 9.0);
    }
}
