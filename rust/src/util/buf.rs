//! Reusable-buffer idioms shared by the decode hot path.

/// Resize a reusable nested-rows buffer to exactly `n` cleared rows.
///
/// Surviving rows keep their heap capacity, which is what makes the
/// hot-path score/selection scratch allocation-free in steady state:
/// with a constant `n` (e.g. the KV-head count) and stable row lengths,
/// repeated calls never touch the allocator.
pub fn resize_rows<T>(out: &mut Vec<Vec<T>>, n: usize) {
    out.truncate(n);
    while out.len() < n {
        out.push(Vec::new());
    }
    for row in out.iter_mut() {
        row.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_shrinks_and_retains_capacity() {
        let mut rows: Vec<Vec<i32>> = Vec::new();
        resize_rows(&mut rows, 3);
        assert_eq!(rows, vec![Vec::<i32>::new(); 3]);
        rows[0].extend_from_slice(&[1, 2, 3, 4]);
        let cap = rows[0].capacity();
        resize_rows(&mut rows, 2);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.is_empty()));
        assert_eq!(rows[0].capacity(), cap, "row capacity must survive");
        resize_rows(&mut rows, 5);
        assert_eq!(rows.len(), 5);
    }
}
