//! Minimal JSON parser / writer.
//!
//! The offline vendor set has no serde facade, so the manifest, fixtures,
//! configs and experiment reports use this ~300-line implementation. It
//! supports the full JSON grammar minus exotic number forms; numbers are
//! parsed as f64 (the manifest only contains small integers and floats).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use BTreeMap for deterministic serialisation.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let s = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        Self::parse(&s)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Array of numbers -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.as_f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- construction helpers --------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.to_string())).collect())
    }

    // ---- serialisation ---------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i);
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            bail!("truncated UTF-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_usize().unwrap(), 1);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "c");
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"x",null,true],"m":{"n":-7}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse("\"héllo \\u0041\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo A");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Json::parse(r#"{"a": 1.5}"#).unwrap();
        assert!(v.get("a").unwrap().as_usize().is_err());
        assert!(v.get("missing").is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
    }
}
