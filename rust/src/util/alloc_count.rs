//! Armed counting allocator for allocation-regression gates.
//!
//! One shared implementation for every binary that asserts
//! zero-steady-state allocations (`benches/decode_hot_path.rs`,
//! `tests/prefill_alloc.rs`), so the two gates can never diverge in what
//! they measure. Each binary registers its own instance:
//!
//! ```ignore
//! use seerattn::util::alloc_count::{count_allocs, CountingAlloc};
//!
//! #[global_allocator]
//! static GLOBAL: CountingAlloc = CountingAlloc;
//!
//! let allocs = count_allocs(|| hot_path());
//! assert_eq!(allocs, 0);
//! ```
//!
//! Counting is gated on an armed flag so the harness's own bookkeeping
//! (result series, JSON building) stays out of the tally. `dealloc` is
//! deliberately uncounted: the gates assert "no heap traffic acquired",
//! and frees of pre-warm buffers are not a regression. Arm from a single
//! thread only — concurrent allocating threads would pollute the count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ARMED: AtomicBool = AtomicBool::new(false);

pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Run `f` with allocation counting armed; returns the allocation count.
/// Only meaningful when [`CountingAlloc`] is the registered global
/// allocator of the running binary.
pub fn count_allocs<F: FnMut()>(mut f: F) -> u64 {
    ARMED.store(true, Ordering::SeqCst);
    let before = ALLOCS.load(Ordering::SeqCst);
    f();
    let after = ALLOCS.load(Ordering::SeqCst);
    ARMED.store(false, Ordering::SeqCst);
    after - before
}
