//! Small self-contained utilities (the build is fully offline, so the
//! crate hand-rolls what would normally come from serde/rand/criterion).

pub mod alloc_count;
pub mod bench;
pub mod buf;
pub mod json;
pub mod rng;
pub mod simd;
pub mod stats;
