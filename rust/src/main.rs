//! `seerattn` CLI — train, distill, reproduce paper exhibits, and serve.

use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use seerattn::coordinator::{server, Engine, EngineConfig, EngineGroup,
                            GroupConfig, ServeConfig};
use seerattn::harness::{self, experiments};
use seerattn::model::ParamStore;
use seerattn::runtime::Runtime;
use seerattn::sparse::Policy;
use seerattn::train::{self, TrainConfig};
use seerattn::util::json::Json;

const USAGE: &str = "\
seerattn — SeerAttention-R reproduction (Rust + JAX + Pallas via XLA/PJRT)

USAGE:
  seerattn train   [--steps N] [--lr X] [--seed S]
  seerattn distill [--block-size B[,B..]] [--steps N] [--lr X]
  seerattn repro   <fig4|fig5|fig6|fig7|fig8|fig9|table1|table2|recall|offload|all>
                   [--n EPISODES] [--bench-budget SECONDS]
  seerattn serve   [--addr HOST:PORT] [--policy P] [--budget TOKENS]
                   [--block-size B] [--shards N] [--gather-threads T]
                   [--max-conns N] [--idle-timeout-ms MS] [--queue-depth N]
                   [--stream] [--deadline-ms MS] [--no-simd]
                   [--defer-retry-ms MS] [--preempt-retries N]
                   [--prefill-chunk TOKENS] [--reactors N]
                   [--prefix-cache] [--prefix-cache-blocks N]
                   [--default-priority interactive|batch]
                   [--restart-limit N] [--wedge-timeout-ms MS]
  seerattn generate [--task easy|hard] [--policy P] [--budget TOKENS] [--n N]
                   [--no-simd]

POLICIES: dense | seer | seer-threshold:T | seer-topp:P | oracle | quest
--gather-threads: 0 = auto (half the cores, max 4), 1 = serial.
--reactors: front-end reactor threads, each with its own SO_REUSEPORT
listener (accept-handoff fallback); 0 = auto (~cores/4, max 8).
--prefill-chunk: prompt tokens prefilled per step, a multiple of
--block-size (default 128; 0 = monolithic prefill, stalls decode).
--prefix-cache: content-addressed prompt-prefix reuse — shared
block-aligned prefixes map cached KV pages and gate blocks instead of
re-prefilling (--prefix-cache-blocks caps cached blocks; 0 = unbounded,
LRU-evicted under pool pressure either way).
--restart-limit: respawns a crashed shard gets before it is retired
dark (default 3); --wedge-timeout-ms: heartbeat stall that
circuit-breaks a shard out of routing (default 1500).
`serve` drains gracefully on SIGTERM: stop accepting, finish
in-flight requests, exit 0 with the final metrics report.
--no-simd pins the host hot path to the bit-identical scalar kernels
(auto-dispatch picks AVX2+FMA / NEON when the CPU has them).
Artifacts are read from ./artifacts (override: SEERATTN_ARTIFACTS).";

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::HashMap::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Args { positional, flags }
}

impl Args {
    fn usize_flag(&self, name: &str, default: usize) -> usize {
        self.flags
            .get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --{name}")))
            .unwrap_or(default)
    }

    fn f64_flag(&self, name: &str, default: f64) -> f64 {
        self.flags
            .get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --{name}")))
            .unwrap_or(default)
    }

    fn str_flag(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }
}

fn parse_policy(s: &str, budget: usize) -> Result<Policy> {
    Ok(match s {
        "dense" | "full" => Policy::Dense,
        "seer" | "seer-budget" => Policy::GateBudget { budget_tokens: budget },
        "oracle" => Policy::Oracle { budget_tokens: budget },
        "quest" => Policy::Quest { budget_tokens: budget },
        other => {
            if let Some(t) = other.strip_prefix("seer-threshold:") {
                Policy::GateThreshold { threshold: t.parse()? }
            } else if let Some(t) = other.strip_prefix("seer-topp:") {
                Policy::GateTopP { p: t.parse()? }
            } else {
                bail!("unknown policy {other:?}")
            }
        }
    })
}

fn write_report(name: &str, steps: usize, rep: &train::TrainReport) -> Result<()> {
    let losses = Json::Arr(
        rep.losses
            .iter()
            .map(|(s, l)| Json::Arr(vec![Json::Num(*s as f64), Json::Num(*l)]))
            .collect(),
    );
    let j = Json::obj(vec![
        ("steps", Json::Num(steps as f64)),
        ("tokens", Json::Num(rep.tokens_seen as f64)),
        ("wall_s", Json::Num(rep.wall_s)),
        ("final_loss", Json::Num(rep.final_loss())),
        ("losses", losses),
    ]);
    let p = harness::results_dir().join(format!("{name}.json"));
    std::fs::write(&p, j.to_string())?;
    println!("wrote {}", p.display());
    Ok(())
}

fn cmd_train(args: &Args, dir: &PathBuf) -> Result<()> {
    let tc = TrainConfig {
        steps: args.usize_flag("steps", 400),
        lr_max: args.f64_flag("lr", 1e-3),
        seed: args.usize_flag("seed", 0) as u64,
        ..Default::default()
    };
    let rt = Runtime::load(dir)?;
    let start = if args.flags.contains_key("resume")
        && train::model_ckpt_path(dir).exists()
    {
        train::model_ckpt_path(dir)
    } else {
        dir.join("model_init.bin")
    };
    let mut params = ParamStore::load(&start, &rt.manifest.params)?;
    println!("pretraining {} params for {} steps (from {}) ...",
             params.numel(), tc.steps, start.display());
    let rep = train::pretrain(&rt, &mut params, &tc, |s, l| {
        println!("  step {s:>5}  loss {l:.4}");
    })?;
    params.save(&train::model_ckpt_path(dir))?;
    println!("saved {} ({:.1}s, {:.1} tok/s)", train::model_ckpt_path(dir).display(),
             rep.wall_s, rep.tokens_seen as f64 / rep.wall_s);
    write_report("pretrain", tc.steps, &rep)
}

fn cmd_distill(args: &Args, dir: &PathBuf) -> Result<()> {
    let blocks: Vec<usize> = args
        .str_flag("block-size", "8,16,32,64")
        .split(',')
        .map(|s| s.parse().map_err(|_| anyhow!("bad block size {s}")))
        .collect::<Result<_>>()?;
    let tc = TrainConfig {
        steps: args.usize_flag("steps", 150),
        lr_max: args.f64_flag("lr", 1e-3),
        seed: args.usize_flag("seed", 0) as u64,
        ..Default::default()
    };
    let rt = Runtime::load(dir)?;
    let params = {
        let trained = train::model_ckpt_path(dir);
        let p = if trained.exists() { trained } else { dir.join("model_init.bin") };
        ParamStore::load(&p, &rt.manifest.params)?
    };
    for bs in blocks {
        let mut gates = ParamStore::load(&dir.join("gate_init.bin"),
                                         &rt.manifest.gate_params)?;
        println!("distilling AttnGate (block {bs}) for {} steps ...", tc.steps);
        let rep = train::distill(&rt, &params, &mut gates, bs, &tc, |s, l| {
            println!("  step {s:>5}  kl {l:.5}");
        })?;
        gates.save(&train::gate_ckpt_path(dir, bs))?;
        println!("saved {} ({:.1}s)", train::gate_ckpt_path(dir, bs).display(),
                 rep.wall_s);
        write_report(&format!("distill_bs{bs}"), tc.steps, &rep)?;
    }
    Ok(())
}

fn cmd_repro(args: &Args, dir: &PathBuf) -> Result<()> {
    let what = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("repro needs an experiment name\n{USAGE}"))?
        .as_str();
    let n = args.usize_flag("n", 48);
    let bench_budget = args.f64_flag("bench-budget", 2.0);
    match what {
        "fig4" => experiments::fig4(dir, n)?,
        "fig5" => experiments::fig5(dir, n)?,
        "fig6" => experiments::fig6(dir, bench_budget)?,
        "fig7" => experiments::fig7(dir, n)?,
        "fig8" => experiments::fig8(dir, n)?,
        "fig9" => experiments::fig9(dir, n)?,
        "table1" => experiments::table1(dir, n)?,
        "table2" => experiments::table2(dir)?,
        "recall" => experiments::recall(dir, n)?,
        "offload" => experiments::offload(dir, n)?,
        "all" => {
            experiments::fig4(dir, n)?;
            experiments::fig5(dir, n)?;
            experiments::fig6(dir, bench_budget)?;
            experiments::fig7(dir, n)?;
            experiments::fig8(dir, n)?;
            experiments::fig9(dir, n)?;
            experiments::table1(dir, n)?;
            experiments::table2(dir)?;
            experiments::recall(dir, n.min(16))?;
            experiments::offload(dir, n.min(16))?;
        }
        other => bail!("unknown experiment {other:?}\n{USAGE}"),
    }
    Ok(())
}

fn cmd_serve(args: &Args, dir: &PathBuf) -> Result<()> {
    let budget = args.usize_flag("budget", 128);
    let policy = parse_policy(&args.str_flag("policy", "seer"), budget)?;
    let ecfg = EngineConfig {
        policy,
        block_size: args.usize_flag("block-size", 16),
        max_new: args.usize_flag("max-new", 64),
        // 0 = auto (GatherPool::default_lanes), 1 = serial.
        gather_threads: args.usize_flag("gather-threads", 0),
        // Single carrier for --no-simd: Engine::new pins the
        // process-global dispatch when this is false.
        simd: !args.flags.contains_key("no-simd"),
        // Preemptions a request survives (requeue + re-prefill) before
        // it is terminated with "resource_exhausted".
        preempt_retries: args.usize_flag("preempt-retries", 3) as u32,
        // Prefill tokens staged per engine step (0 = monolithic); must
        // be a multiple of --block-size so gate blocks stay aligned.
        prefill_chunk: args.usize_flag("prefill-chunk", 128),
        // Content-addressed prefix cache: admitted prompts reuse KV
        // pages + gate blocks for any cached block-aligned prefix.
        prefix_cache: args.flags.contains_key("prefix-cache"),
        prefix_cache_blocks: args.usize_flag("prefix-cache-blocks", 0),
        ..Default::default()
    };
    // Resolve the reactor count up front: the group needs one completion
    // lane per front-end reactor (0 = auto from the core count).
    let reactors = server::resolve_reactors(args.usize_flag("reactors", 1));
    let gcfg = GroupConfig {
        shards: args.usize_flag("shards", 1),
        // Bounded per-shard overflow queue; beyond `batch + queue_depth`
        // on every shard, clients get a structured `overloaded` reply.
        queue_depth: args.usize_flag("queue-depth", 32),
        // Retry hint carried on "deferred" (KV page headroom) replies.
        defer_retry_ms: args.usize_flag("defer-retry-ms", 25) as u64,
        // Prefix-affinity routing + reservation discounts only make
        // sense when the shards actually cache prefixes.
        prefix_routing: args.flags.contains_key("prefix-cache"),
        lanes: reactors,
        // Shard supervision: a stalled shard is circuit-broken out of
        // routing past this heartbeat silence...
        wedge_timeout: std::time::Duration::from_millis(
            args.usize_flag("wedge-timeout-ms", 1500) as u64),
        // ...and a crashed shard is respawned (queued + in-flight work
        // rescued) at most this many times before it goes dark.
        restart_limit: args.usize_flag("restart-limit", 3) as u32,
        ..Default::default()
    };
    let default_priority = {
        let s = args.str_flag("default-priority", "interactive");
        seerattn::coordinator::Priority::from_wire(&s)
            .ok_or_else(|| anyhow!("unknown --default-priority {s:?} \
                                    (want interactive|batch)"))?
    };
    let scfg = ServeConfig {
        max_conns: args.usize_flag("max-conns", 256),
        idle_timeout: std::time::Duration::from_millis(
            args.usize_flag("idle-timeout-ms", 30_000) as u64),
        limit: None,
        // Stream token deltas unless a request opts out with
        // {"stream": false}; without the flag, requests opt in.
        stream_by_default: args.flags.contains_key("stream"),
        // Fleet-wide default deadline; 0 (the default) = unbounded.
        // Requests may override with {"deadline_ms": N}.
        deadline: match args.usize_flag("deadline-ms", 0) {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms as u64)),
        },
        // Scheduling class for requests without a "priority" field.
        default_priority,
        // Front-end reactor threads (SO_REUSEPORT listeners, or accept
        // handoff when the kernel lacks the option).
        reactors,
        // The CLI owns the process, so SIGTERM means "drain and exit
        // 0" (libraries embedding serve() keep the default false).
        drain_on_signal: true,
    };
    // Each shard thread constructs its own runtime + engine (the engine
    // holds an Rc and never crosses threads); the factory just captures
    // the artifact dir and the shared config.
    let dir = dir.clone();
    let group = EngineGroup::with_config(gcfg, move |_shard| {
        let (rt, params) = harness::load_runtime_and_params(&dir)?;
        let rt = Rc::new(rt);
        let gates = harness::load_gates(&rt, &dir, ecfg.block_size)?;
        Engine::new(rt, params, gates, ecfg)
    })?;
    // Shard threads apply ecfg.simd in Engine::new; derive the label
    // from the config rather than racing the global dispatch state.
    let simd_label = if ecfg.simd {
        seerattn::util::simd::target_name()
    } else {
        "scalar (--no-simd)"
    };
    eprintln!("[seerattn] {} engine shard(s), policy {}, simd {}", gcfg.shards,
              policy.name(), simd_label);
    server::serve(group, &args.str_flag("addr", "127.0.0.1:7077"), scfg)
}

fn cmd_generate(args: &Args, dir: &PathBuf) -> Result<()> {
    use seerattn::workload::reasoning::TaskConfig;
    let budget = args.usize_flag("budget", 128);
    let policy = parse_policy(&args.str_flag("policy", "seer"), budget)?;
    let task = match args.str_flag("task", "hard").as_str() {
        "easy" => TaskConfig::easy(),
        _ => TaskConfig::hard(),
    };
    let n = args.usize_flag("n", 8);
    let ecfg = EngineConfig {
        policy,
        block_size: args.usize_flag("block-size", 16),
        simd: !args.flags.contains_key("no-simd"),
        ..Default::default()
    };
    let (rt, params) = harness::load_runtime_and_params(dir)?;
    let rt = Rc::new(rt);
    let gates = harness::load_gates(&rt, dir, ecfg.block_size)?;
    let mut engine = Engine::new(rt, params, gates, ecfg)?;
    let max_new = harness::max_new_for(&task, engine.max_seq());
    let o = harness::eval_policy(&mut engine, task, n, 123, max_new)?;
    println!("policy={} n={} accuracy={:.1}% answered={:.1}% gen_len={:.1} ({:.1}s)",
             engine.ecfg.policy.name(), o.n, 100.0 * o.accuracy,
             100.0 * o.answered_frac, o.mean_gen_len, o.wall_s);
    println!("{}", engine.metrics.report());
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let args = parse_args(&argv);
    let dir = harness::require_artifacts()?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args, &dir),
        Some("distill") => cmd_distill(&args, &dir),
        Some("repro") => cmd_repro(&args, &dir),
        Some("serve") => cmd_serve(&args, &dir),
        Some("generate") => cmd_generate(&args, &dir),
        Some("dump-batch") => {
            // Debug: write one packed training batch as JSON (ids+weights).
            use seerattn::util::rng::Rng;
            use seerattn::workload::{corpus, Vocab};
            let mut rng = Rng::new(args.usize_flag("seed", 0) as u64);
            let (ids, ws) = corpus::pack_batch(&Vocab::default(),
                &corpus::default_mixture(), 2, 512, &mut rng);
            let j = Json::obj(vec![
                ("ids", Json::Arr(ids.iter().map(|&t| Json::Num(t as f64)).collect())),
                ("ws", Json::Arr(ws.iter().map(|&w| Json::Num(w as f64)).collect())),
            ]);
            std::fs::create_dir_all("results").ok();
            std::fs::write("results/batch_dump.json", j.to_string())?;
            println!("wrote results/batch_dump.json");
            Ok(())
        }
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}
