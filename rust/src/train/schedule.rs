//! Learning-rate schedule: linear warmup then cosine decay (paper §4.1:
//! "AdamW optimizer and a learning rate of 1e-3 with cosine decay").

#[derive(Debug, Clone, Copy)]
pub struct CosineSchedule {
    pub lr_max: f64,
    pub warmup: usize,
    pub total: usize,
}

impl CosineSchedule {
    pub fn lr(&self, step: usize) -> f64 {
        if self.warmup > 0 && step < self.warmup {
            return self.lr_max * (step + 1) as f64 / self.warmup as f64;
        }
        let span = (self.total.saturating_sub(self.warmup)).max(1) as f64;
        let t = (step - self.warmup.min(step)) as f64 / span;
        let t = t.clamp(0.0, 1.0);
        0.5 * self.lr_max * (1.0 + (std::f64::consts::PI * t).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = CosineSchedule { lr_max: 1e-3, warmup: 10, total: 100 };
        assert!((s.lr(0) - 1e-4).abs() < 1e-12);
        assert!((s.lr(4) - 5e-4).abs() < 1e-12);
        assert!((s.lr(9) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn cosine_decays_to_zero() {
        let s = CosineSchedule { lr_max: 1e-3, warmup: 10, total: 100 };
        assert!((s.lr(10) - 1e-3).abs() < 1e-9, "peak right after warmup");
        let mid = s.lr(55);
        assert!(mid < 1e-3 && mid > 0.0);
        assert!(s.lr(100) < 1e-9);
        // Monotone decay after warmup.
        let mut prev = s.lr(10);
        for t in 11..=100 {
            let cur = s.lr(t);
            assert!(cur <= prev + 1e-15);
            prev = cur;
        }
    }

    #[test]
    fn no_warmup_edge() {
        let s = CosineSchedule { lr_max: 1.0, warmup: 0, total: 10 };
        assert!((s.lr(0) - 1.0).abs() < 1e-12);
        assert!(s.lr(10) < 1e-9);
        // Steps past total stay clamped at 0.
        assert!(s.lr(50) < 1e-9);
    }
}
