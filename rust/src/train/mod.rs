//! Training drivers: the Rust side of pretraining and AttnGate
//! distillation. Each step is one fused AOT executable (fwd + bwd +
//! AdamW); Rust owns the parameter/optimizer buffers, the LR schedule
//! (cosine with warmup, §4.1), the data pipeline, and checkpointing.

pub mod schedule;

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::model::{ModelConfig, ParamStore};
use crate::runtime::{Arg, HostTensor, Runtime};
use crate::util::rng::Rng;
use crate::workload::corpus;
use crate::workload::Vocab;
use schedule::CosineSchedule;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr_max: f64,
    pub warmup: usize,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 400, lr_max: 1e-3, warmup: 20, seed: 0, log_every: 10 }
    }
}

#[derive(Debug, Clone)]
pub struct TrainReport {
    /// (step, loss) samples.
    pub losses: Vec<(usize, f64)>,
    pub tokens_seen: u64,
    pub wall_s: f64,
}

impl TrainReport {
    pub fn final_loss(&self) -> f64 {
        self.losses.last().map(|(_, l)| *l).unwrap_or(f64::NAN)
    }
}

/// Pretrain the base model on the synthetic reasoning corpus.
/// `params` is updated in place; Adam state lives for the run.
pub fn pretrain(rt: &Runtime, params: &mut ParamStore, tc: &TrainConfig,
                mut on_log: impl FnMut(usize, f64)) -> Result<TrainReport> {
    let cfg = ModelConfig::from_json(&rt.manifest.model)?;
    let tb = rt.manifest.aot.get("train_batch")?.as_usize()?;
    let ts = rt.manifest.aot.get("train_len")?.as_usize()?;
    let n_p = rt.manifest.params.len();
    let mut m = ParamStore::zeros(&rt.manifest.params);
    let mut v = ParamStore::zeros(&rt.manifest.params);
    let sched = CosineSchedule { lr_max: tc.lr_max, warmup: tc.warmup, total: tc.steps };
    let vocab = Vocab::default();
    let mixture = corpus::default_mixture();
    let mut rng = Rng::new(tc.seed);
    let mut report = TrainReport { losses: Vec::new(), tokens_seen: 0, wall_s: 0.0 };
    let t0 = Instant::now();
    let _ = cfg;
    for step in 0..tc.steps {
        let (ids, ws) = corpus::pack_batch(&vocab, &mixture, tb, ts, &mut rng);
        let ids_t = HostTensor::i32(vec![tb, ts], ids);
        let ws_t = HostTensor::f32(vec![tb, ts], ws);
        let step_t = HostTensor::scalar_f32(step as f32);
        let lr_t = HostTensor::scalar_f32(sched.lr(step) as f32);
        let mut args: Vec<Arg> = Vec::with_capacity(3 * n_p + 4);
        for t in &params.tensors {
            args.push(Arg::Host(t));
        }
        for t in &m.tensors {
            args.push(Arg::Host(t));
        }
        for t in &v.tensors {
            args.push(Arg::Host(t));
        }
        args.push(Arg::Host(&step_t));
        args.push(Arg::Host(&lr_t));
        args.push(Arg::Host(&ids_t));
        args.push(Arg::Host(&ws_t));
        let mut outs = rt.call("pretrain_step", &args)?;
        let loss = outs
            .pop()
            .ok_or_else(|| anyhow!("missing loss output"))?
            .as_f32()?[0] as f64;
        let v_new = outs.split_off(2 * n_p);
        let m_new = outs.split_off(n_p);
        params.set_all(outs)?;
        m.set_all(m_new)?;
        v.set_all(v_new)?;
        report.tokens_seen += (tb * ts) as u64;
        if step % tc.log_every == 0 || step + 1 == tc.steps {
            report.losses.push((step, loss));
            on_log(step, loss);
        }
    }
    report.wall_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

/// Distill the AttnGate against the frozen base model (§2.3) for one
/// block size. `gates` is updated in place.
pub fn distill(rt: &Runtime, params: &ParamStore, gates: &mut ParamStore,
               block_size: usize, tc: &TrainConfig,
               mut on_log: impl FnMut(usize, f64)) -> Result<TrainReport> {
    let db = rt.manifest.aot.get("distill_batch")?.as_usize()?;
    let ds = rt.manifest.aot.get("distill_len")?.as_usize()?;
    let exe = format!("distill_step_bs{block_size}");
    let n_g = rt.manifest.gate_params.len();
    let mut gm = ParamStore::zeros(&rt.manifest.gate_params);
    let mut gv = ParamStore::zeros(&rt.manifest.gate_params);
    let sched = CosineSchedule { lr_max: tc.lr_max, warmup: tc.warmup, total: tc.steps };
    let vocab = Vocab::default();
    let mixture = corpus::default_mixture();
    let mut rng = Rng::new(tc.seed.wrapping_add(0x5eed));
    let mut report = TrainReport { losses: Vec::new(), tokens_seen: 0, wall_s: 0.0 };
    let t0 = Instant::now();
    for step in 0..tc.steps {
        let (ids, _ws) = corpus::pack_batch(&vocab, &mixture, db, ds, &mut rng);
        let ids_t = HostTensor::i32(vec![db, ds], ids);
        let step_t = HostTensor::scalar_f32(step as f32);
        let lr_t = HostTensor::scalar_f32(sched.lr(step) as f32);
        let mut args: Vec<Arg> = Vec::new();
        for t in &params.tensors {
            args.push(Arg::Host(t));
        }
        for t in &gates.tensors {
            args.push(Arg::Host(t));
        }
        for t in &gm.tensors {
            args.push(Arg::Host(t));
        }
        for t in &gv.tensors {
            args.push(Arg::Host(t));
        }
        args.push(Arg::Host(&step_t));
        args.push(Arg::Host(&lr_t));
        args.push(Arg::Host(&ids_t));
        let mut outs = rt.call(&exe, &args)?;
        let kl = outs
            .pop()
            .ok_or_else(|| anyhow!("missing kl output"))?
            .as_f32()?[0] as f64;
        let gv_new = outs.split_off(2 * n_g);
        let gm_new = outs.split_off(n_g);
        gates.set_all(outs)?;
        gm.set_all(gm_new)?;
        gv.set_all(gv_new)?;
        report.tokens_seen += (db * ds) as u64;
        if step % tc.log_every == 0 || step + 1 == tc.steps {
            report.losses.push((step, kl));
            on_log(step, kl);
        }
    }
    report.wall_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

/// Standard checkpoint locations under the artifacts dir.
pub fn model_ckpt_path(dir: &Path) -> std::path::PathBuf {
    dir.join("model_trained.bin")
}

pub fn gate_ckpt_path(dir: &Path, block_size: usize) -> std::path::PathBuf {
    dir.join(format!("gate_bs{block_size}.bin"))
}
