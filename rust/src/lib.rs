//! # seerattn — SeerAttention-R reproduction
//!
//! A three-layer reproduction of *SeerAttention-R: Sparse Attention
//! Adaptation for Long Reasoning* (2025):
//!
//! * **L1** (build time): Pallas kernels — the block-sparse flash-decoding
//!   kernel (§3.3) and the ground-truth-generating flash forward (§2.3) —
//!   lowered with `interpret=True` into plain HLO.
//! * **L2** (build time): a GQA transformer + AttnGate in JAX, AOT-lowered
//!   to HLO text executables (`artifacts/*.hlo.txt`).
//! * **L3** (this crate, the request path): a serving coordinator that
//!   loads the executables through the PJRT CPU client (`xla` crate) and
//!   owns everything the paper's system owns at inference time — the
//!   paged KV cache, the K compression cache (§3.2), the AttnGate scoring
//!   + budget/threshold sparsification (§3.1), the Quest and oracle
//!   baselines, continuous batching, and the distillation/pretraining
//!   drivers.
//!
//! Python never runs on the request path: after `make artifacts` the
//! binary is self-contained.
//!
//! Feature `pjrt` (off by default) enables everything that links against
//! the PJRT CPU client via the `xla` crate: the runtime engine, the
//! decode/serving coordinator, the training drivers, and the experiment
//! harness. The default feature set is pure host Rust — gate math, sparse
//! selection, KV caching, staging arenas, workloads, utilities — and
//! builds/tests fully offline.

pub mod coordinator;
pub mod gate;
pub mod harness;
pub mod kvcache;
pub mod model;
pub mod runtime;
pub mod sparse;
#[cfg(feature = "pjrt")]
pub mod train;
pub mod util;
pub mod workload;
