//! Table/series reporting: markdown to stdout, CSV to results/.

use std::path::Path;

use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            s
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.markdown());
    }

    pub fn save_csv(&self, path: &Path) -> Result<()> {
        let mut s = self.headers.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        std::fs::write(path, s)?;
        Ok(())
    }
}

pub fn f(x: f64) -> String {
    format!("{x:.3}")
}

pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_and_csv() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "x".into()]);
        t.row(vec!["22".into(), "yy".into()]);
        let md = t.markdown();
        assert!(md.contains("### demo"));
        assert!(md.contains("| 22 | yy |"));
        let dir = std::env::temp_dir().join(format!("seerattn_rep_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv");
        t.save_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s.lines().count(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
