//! One runner per paper exhibit. Every runner prints the table/series the
//! paper reports (scaled per DESIGN.md §1) and writes a CSV next to it.

use std::path::Path;
use std::rc::Rc;

use anyhow::Result;

use super::report::{f, f1, pct, Table};
use super::{eval_policy, load_gates, load_runtime_and_params, max_new_for,
            results_dir, EvalOutcome};
use crate::coordinator::{Engine, EngineConfig};
use crate::model::ParamStore;
use crate::runtime::{Arg, HostTensor, Runtime};
use crate::sparse::Policy;
use crate::util::bench::bench;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::reasoning::TaskConfig;

pub const BUDGETS: [usize; 4] = [64, 128, 256, 384];

fn engine(rt: &Rc<Runtime>, dir: &Path, ecfg: EngineConfig) -> Result<Engine> {
    let params = {
        let trained = crate::train::model_ckpt_path(dir);
        let path = if trained.exists() { trained } else { dir.join("model_init.bin") };
        ParamStore::load(&path, &rt.manifest.params)?
    };
    let gates = load_gates(rt, dir, ecfg.block_size)?;
    Engine::new(rt.clone(), params, gates, ecfg)
}

fn tasks() -> Vec<(&'static str, TaskConfig)> {
    vec![("easy(1-hop)", TaskConfig::easy()), ("hard(3-hop)", TaskConfig::hard())]
}

fn run_one(rt: &Rc<Runtime>, dir: &Path, ecfg: EngineConfig, task: TaskConfig,
           n: usize, seed: u64) -> Result<EvalOutcome> {
    let mut eng = engine(rt, dir, ecfg)?;
    let max_new = max_new_for(&task, eng.max_seq());
    eval_policy(&mut eng, task, n, seed, max_new)
}

/// Fig 4 — oracle sparse accuracy across block sizes and budgets.
pub fn fig4(dir: &Path, n: usize) -> Result<()> {
    let (rt, _params) = load_runtime_and_params(dir)?;
    let rt = Rc::new(rt);
    let mut t = Table::new(
        "Fig 4 — oracle block-sparse accuracy (paper: lossless >= 2k budget; \
         degradation only at the smallest budget x largest block)",
        &["task", "block", "budget", "accuracy", "answered", "gen_len"],
    );
    for (tname, task) in tasks() {
        // Dense reference first.
        let o = run_one(&rt, dir, EngineConfig { policy: Policy::Dense,
                                                 ..Default::default() },
                        task, n, 40)?;
        t.row(vec![tname.into(), "-".into(), "dense".into(), pct(o.accuracy),
                   pct(o.answered_frac), f1(o.mean_gen_len)]);
        for &bs in &[8usize, 16, 32] {
            for &budget in &BUDGETS {
                let ecfg = EngineConfig {
                    policy: Policy::Oracle { budget_tokens: budget },
                    block_size: bs,
                    ..Default::default()
                };
                let o = run_one(&rt, dir, ecfg, task, n, 40)?;
                t.row(vec![tname.into(), bs.to_string(), budget.to_string(),
                           pct(o.accuracy), pct(o.answered_frac),
                           f1(o.mean_gen_len)]);
            }
        }
    }
    t.print();
    t.save_csv(&results_dir().join("fig4.csv"))?;
    Ok(())
}

/// Fig 5 — the main comparison: Full vs SeerAttention-R vs Quest.
pub fn fig5(dir: &Path, n: usize) -> Result<()> {
    let (rt, _params) = load_runtime_and_params(dir)?;
    let rt = Rc::new(rt);
    let mut t = Table::new(
        "Fig 5 — accuracy vs token budget (paper: Seer near-lossless at mid \
         budget, Quest below at every budget; block 64 -> scaled block 16)",
        &["task", "policy", "budget", "accuracy", "answered", "gen_len",
          "kv_touch"],
    );
    for (tname, task) in tasks() {
        let o = run_one(&rt, dir, EngineConfig { policy: Policy::Dense,
                                                 ..Default::default() },
                        task, n, 41)?;
        t.row(vec![tname.into(), "full".into(), "-".into(), pct(o.accuracy),
                   pct(o.answered_frac), f1(o.mean_gen_len),
                   f(o.kv_touch_fraction)]);
        for &budget in &BUDGETS {
            for (pname, policy) in [
                ("seer", Policy::GateBudget { budget_tokens: budget }),
                ("quest", Policy::Quest { budget_tokens: budget }),
            ] {
                let ecfg = EngineConfig { policy, block_size: 16,
                                          ..Default::default() };
                let o = run_one(&rt, dir, ecfg, task, n, 41)?;
                t.row(vec![tname.into(), pname.into(), budget.to_string(),
                           pct(o.accuracy), pct(o.answered_frac),
                           f1(o.mean_gen_len), f(o.kv_touch_fraction)]);
            }
        }
    }
    t.print();
    t.save_csv(&results_dir().join("fig5.csv"))?;
    Ok(())
}

/// Fig 6 — block-sparse flash-decoding kernel speedup vs the dense
/// baseline, across seqlen x batch x sparsity (paper: up to 9x at 0.9
/// sparsity on H100; here shape-checked on the CPU PJRT backend).
pub fn fig6(dir: &Path, budget_s: f64) -> Result<()> {
    let rt = Runtime::load(dir)?;
    let mut t = Table::new(
        "Fig 6 — sparse decode kernel speedup over dense flash-decode \
         (theoretical = 1/(1-sparsity))",
        &["seqlen", "batch", "sparsity", "dense_ms", "sparse_ms", "speedup",
          "theoretical"],
    );
    let kb = &rt.manifest.kbench;
    let heads = kb.get("n_heads")?.as_usize()?;
    let hkv = kb.get("n_kv_heads")?.as_usize()?;
    let dh = kb.get("head_dim")?.as_usize()?;
    let bs = kb.get("block_size")?.as_usize()?;
    let mut rng = Rng::new(7);
    let points = rt.manifest.kbench_points.clone();
    let mut dense_cache: std::collections::HashMap<String, f64> =
        std::collections::HashMap::new();
    for p in &points {
        let (s, b) = (p.seqlen, p.batch);
        // KV (and q/idx) are uploaded ONCE and kept device-resident — the
        // paper's setting (the decode kernel reads the KV cache from HBM;
        // it does not re-ship it per call). Before this change the upload
        // memcpy added a fixed ~1.4 ms/call at s=8k and capped measured
        // speedups near 2x (see EXPERIMENTS.md §Perf).
        let q = rt.upload(&HostTensor::f32(vec![b, heads, dh],
            (0..b * heads * dh).map(|_| rng.normal() as f32).collect()))?;
        let k = rt.upload(&HostTensor::f32(vec![b, hkv, s, dh],
            (0..b * hkv * s * dh).map(|_| rng.f32() - 0.5).collect()))?;
        let v = rt.upload(&HostTensor::f32(vec![b, hkv, s, dh],
            (0..b * hkv * s * dh).map(|_| rng.f32() - 0.5).collect()))?;
        let sl = rt.upload(&HostTensor::i32(vec![b], vec![s as i32; b]))?;
        let dense_ms = if let Some(d) = dense_cache.get(&p.dense) {
            *d
        } else {
            let r = bench(&p.dense, 1, 3, budget_s, || {
                rt.call(&p.dense, &[Arg::Dev(&q), Arg::Dev(&k), Arg::Dev(&v),
                                    Arg::Dev(&sl)])
                    .unwrap();
            });
            dense_cache.insert(p.dense.clone(), r.median_s);
            r.median_s
        };
        // Random ascending distinct block indices, k_sel per kv head.
        let nblk = s / bs;
        let mut idx = Vec::with_capacity(b * hkv * p.k_sel);
        for _ in 0..b * hkv {
            let mut sel = rng.sample_distinct(nblk, p.k_sel);
            sel.sort_unstable();
            idx.extend(sel.into_iter().map(|x| x as i32));
        }
        let idx_t = rt.upload(&HostTensor::i32(vec![b, hkv, p.k_sel], idx))?;
        let r = bench(&p.sparse, 1, 3, budget_s, || {
            rt.call(&p.sparse, &[Arg::Dev(&q), Arg::Dev(&k), Arg::Dev(&v),
                                 Arg::Dev(&idx_t), Arg::Dev(&sl)])
                .unwrap();
        });
        let speedup = dense_ms / r.median_s;
        let theo = nblk as f64 / p.k_sel as f64;
        t.row(vec![s.to_string(), b.to_string(), format!("{:.1}", p.sparsity),
                   f(dense_ms * 1e3), f(r.median_s * 1e3), format!("{speedup:.2}x"),
                   format!("{theo:.2}x")]);
    }
    t.print();
    t.save_csv(&results_dir().join("fig6.csv"))?;
    Ok(())
}

/// Fig 7 — block-size ablation at fixed budget (Seer flat, Quest degrades).
pub fn fig7(dir: &Path, n: usize) -> Result<()> {
    let (rt, _params) = load_runtime_and_params(dir)?;
    let rt = Rc::new(rt);
    let task = TaskConfig::hard();
    let budget = 128;
    let mut t = Table::new(
        "Fig 7 — accuracy vs sparse block size at fixed budget (scaled: \
         paper 16..128 @ 4k -> 8..64 @ 128)",
        &["policy", "block", "accuracy", "answered", "gen_len"],
    );
    for &bs in &[8usize, 16, 32, 64] {
        for (pname, policy) in [
            ("seer", Policy::GateBudget { budget_tokens: budget }),
            ("quest", Policy::Quest { budget_tokens: budget }),
        ] {
            let ecfg = EngineConfig { policy, block_size: bs, ..Default::default() };
            let o = run_one(&rt, dir, ecfg, task, n, 42)?;
            t.row(vec![pname.into(), bs.to_string(), pct(o.accuracy),
                       pct(o.answered_frac), f1(o.mean_gen_len)]);
        }
    }
    t.print();
    t.save_csv(&results_dir().join("fig7.csv"))?;
    Ok(())
}

/// Fig 8 — hybrid dense attention in the first two layers.
pub fn fig8(dir: &Path, n: usize) -> Result<()> {
    let (rt, _params) = load_runtime_and_params(dir)?;
    let rt = Rc::new(rt);
    let task = TaskConfig::hard();
    let mut t = Table::new(
        "Fig 8 — dense attention in the first two layers (paper: helps \
         Quest a lot, Seer marginally)",
        &["policy", "dense_layers", "budget", "accuracy", "gen_len"],
    );
    for &budget in &[64usize, 128] {
        for (pname, policy) in [
            ("seer", Policy::GateBudget { budget_tokens: budget }),
            ("quest", Policy::Quest { budget_tokens: budget }),
        ] {
            for dense_first in [0usize, 2] {
                let ecfg = EngineConfig {
                    policy,
                    dense_first_layers: dense_first,
                    block_size: 16,
                    ..Default::default()
                };
                let o = run_one(&rt, dir, ecfg, task, n, 43)?;
                t.row(vec![pname.into(), dense_first.to_string(),
                           budget.to_string(), pct(o.accuracy),
                           f1(o.mean_gen_len)]);
            }
        }
    }
    t.print();
    t.save_csv(&results_dir().join("fig8.csv"))?;
    Ok(())
}

/// Fig 9 — threshold vs token budget: activated-token distribution and
/// the sparsity/accuracy trade-off.
pub fn fig9(dir: &Path, n: usize) -> Result<()> {
    let (rt, _params) = load_runtime_and_params(dir)?;
    let rt = Rc::new(rt);
    let task = TaskConfig::hard();
    let thresholds = [0.02f32, 0.04, 0.06, 0.09, 0.13];
    let mut t = Table::new(
        "Fig 9b — threshold vs token budget trade-off (activated tokens \
         vs accuracy; paper: threshold slightly better at high sparsity)",
        &["method", "setting", "mean_activated_tok", "accuracy", "gen_len"],
    );
    let mut scatter = Table::new(
        "Fig 9a — activated tokens vs context length (sample)",
        &["method", "setting", "ctx_len", "activated"],
    );
    for &budget in &BUDGETS {
        let ecfg = EngineConfig {
            policy: Policy::GateBudget { budget_tokens: budget },
            block_size: 16,
            ..Default::default()
        };
        let o = run_one(&rt, dir, ecfg, task, n, 44)?;
        t.row(vec!["budget".into(), budget.to_string(),
                   f1(o.mean_activated.unwrap_or(0.0)), pct(o.accuracy),
                   f1(o.mean_gen_len)]);
        for (c, a) in o.activation_points.iter().step_by(37) {
            scatter.row(vec!["budget".into(), budget.to_string(), c.to_string(),
                             f1(*a)]);
        }
    }
    for &th in &thresholds {
        let ecfg = EngineConfig {
            policy: Policy::GateThreshold { threshold: th },
            block_size: 16,
            ..Default::default()
        };
        let o = run_one(&rt, dir, ecfg, task, n, 44)?;
        t.row(vec!["threshold".into(), format!("{th}"),
                   f1(o.mean_activated.unwrap_or(0.0)), pct(o.accuracy),
                   f1(o.mean_gen_len)]);
        for (c, a) in o.activation_points.iter().step_by(37) {
            scatter.row(vec!["threshold".into(), format!("{th}"), c.to_string(),
                             f1(*a)]);
        }
    }
    t.print();
    t.save_csv(&results_dir().join("fig9b.csv"))?;
    scatter.save_csv(&results_dir().join("fig9a.csv"))?;
    println!("(Fig 9a scatter written to results/fig9a.csv, {} points)",
             scatter.rows.len());
    Ok(())
}

/// Table 1 — accuracy vs generation length under inaccurate sparsity.
pub fn table1(dir: &Path, n: usize) -> Result<()> {
    let (rt, _params) = load_runtime_and_params(dir)?;
    let rt = Rc::new(rt);
    let task = TaskConfig::hard();
    let mut t = Table::new(
        "Table 1 — accuracy vs generation length (paper: inaccurate sparse \
         attention inflates reasoning length)",
        &["policy", "budget", "accuracy", "gen_len", "answered"],
    );
    let o = run_one(&rt, dir, EngineConfig { policy: Policy::Dense,
                                             ..Default::default() },
                    task, n, 45)?;
    t.row(vec!["full".into(), "-".into(), pct(o.accuracy), f1(o.mean_gen_len),
               pct(o.answered_frac)]);
    for (pname, mk) in [
        ("quest", (|b: usize| Policy::Quest { budget_tokens: b })
            as fn(usize) -> Policy),
        ("seer", |b: usize| Policy::GateBudget { budget_tokens: b }),
    ] {
        for &budget in &BUDGETS {
            let ecfg = EngineConfig { policy: mk(budget), block_size: 16,
                                      ..Default::default() };
            let o = run_one(&rt, dir, ecfg, task, n, 45)?;
            t.row(vec![pname.into(), budget.to_string(), pct(o.accuracy),
                       f1(o.mean_gen_len), pct(o.answered_frac)]);
        }
    }
    t.print();
    t.save_csv(&results_dir().join("table1.csv"))?;
    Ok(())
}

/// Table 2 — training budget: read the train/distill reports.
pub fn table2(_dir: &Path) -> Result<()> {
    let mut t = Table::new(
        "Table 2 — training budget (paper: 0.4B tokens, 10.9-18.6 GPU-h on \
         MI300x; ours: scaled single-CPU-core wall clock)",
        &["phase", "steps", "tokens", "wall_s", "final_loss"],
    );
    let rd = results_dir();
    for name in ["pretrain", "distill_bs8", "distill_bs16", "distill_bs32",
                 "distill_bs64"] {
        let p = rd.join(format!("{name}.json"));
        if !p.exists() {
            continue;
        }
        let j = Json::parse_file(&p)?;
        t.row(vec![
            name.into(),
            j.get("steps")?.as_usize()?.to_string(),
            j.get("tokens")?.as_usize()?.to_string(),
            f1(j.get("wall_s")?.as_f64()?),
            f(j.get("final_loss")?.as_f64()?),
        ]);
    }
    if t.rows.is_empty() {
        println!("(no training reports found — run `seerattn train` and \
                  `seerattn distill` first)");
    }
    t.print();
    t.save_csv(&rd.join("table2.csv"))?;
    Ok(())
}

/// Gate/Quest selection recall vs the oracle (diagnostic under Figs 5/7).
pub fn recall(dir: &Path, n: usize) -> Result<()> {
    let (rt, _params) = load_runtime_and_params(dir)?;
    let rt = Rc::new(rt);
    let task = TaskConfig::hard();
    let mut t = Table::new(
        "Selection recall vs oracle (diagnostic: why Seer beats Quest)",
        &["policy", "budget", "recall", "accuracy"],
    );
    for &budget in &[64usize, 128, 256] {
        for (pname, policy) in [
            ("seer", Policy::GateBudget { budget_tokens: budget }),
            ("quest", Policy::Quest { budget_tokens: budget }),
        ] {
            let ecfg = EngineConfig { policy, block_size: 16, track_recall: true,
                                      ..Default::default() };
            let o = run_one(&rt, dir, ecfg, task, n, 46)?;
            t.row(vec![pname.into(), budget.to_string(),
                       o.mean_recall.map(f).unwrap_or_else(|| "-".into()),
                       pct(o.accuracy)]);
        }
    }
    t.print();
    t.save_csv(&results_dir().join("recall.csv"))?;
    Ok(())
}

/// KV-offload ablation (§3.2): with the KV cache in a slow tier and a
/// small fast tier, sparse selection turns offloading practical — only
/// the activated blocks move. Reports bytes fetched + hit rate.
pub fn offload(dir: &Path, n: usize) -> Result<()> {
    let (rt, _params) = load_runtime_and_params(dir)?;
    let rt = Rc::new(rt);
    let task = TaskConfig::hard();
    let mut t = Table::new(
        "KV offload ablation — fast tier = 12.5% of pool (paper §3.2: only \
         activated blocks need to be retrieved)",
        &["policy", "fetched_MB", "hit_rate", "sim_fetch_ms/token"],
    );
    for (pname, policy) in [
        ("dense", Policy::Dense),
        ("seer b=256", Policy::GateBudget { budget_tokens: 256 }),
        ("seer b=128", Policy::GateBudget { budget_tokens: 128 }),
        ("seer b=64", Policy::GateBudget { budget_tokens: 64 }),
    ] {
        let mut eng = {
            let mut ecfg = EngineConfig { policy, block_size: 16,
                                          ..Default::default() };
            // fast tier: 1/8 of the page pool
            let params = ParamStore::load(
                &{
                    let tr = crate::train::model_ckpt_path(dir);
                    if tr.exists() { tr } else { dir.join("model_init.bin") }
                },
                &rt.manifest.params)?;
            let gates = load_gates(&rt, dir, ecfg.block_size)?;
            let probe = Engine::new(rt.clone(), params, gates, ecfg)?;
            ecfg.offload_fast_pages = probe.pool_capacity() / 8;
            drop(probe);
            engine(&rt, dir, ecfg)?
        };
        let max_new = max_new_for(&task, eng.max_seq());
        let o = eval_policy(&mut eng, task, n, 47, max_new)?;
        let tiered = eng.offload.as_ref().unwrap();
        let tokens = eng.metrics.tokens_generated.max(1);
        t.row(vec![
            pname.into(),
            format!("{:.2}", tiered.bytes_fetched as f64 / 1e6),
            f(tiered.hit_rate()),
            format!("{:.4}", tiered.simulated_fetch_s * 1e3 / tokens as f64),
        ]);
        let _ = o;
    }
    t.print();
    t.save_csv(&results_dir().join("offload.csv"))?;
    Ok(())
}
