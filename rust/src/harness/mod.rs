//! Experiment harness: one runner per paper table/figure (DESIGN.md §4).
//!
//! Artifact/result path helpers and reporting are always available; the
//! experiment runners and engine builders need the PJRT runtime and are
//! gated behind the `pjrt` feature.

#[cfg(feature = "pjrt")]
pub mod experiments;
pub mod report;

use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::path::Path;

use anyhow::{anyhow, Result};

#[cfg(feature = "pjrt")]
use crate::coordinator::{Engine, EngineConfig, Request};
#[cfg(feature = "pjrt")]
use crate::model::ParamStore;
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
#[cfg(feature = "pjrt")]
use crate::train;
#[cfg(feature = "pjrt")]
use crate::util::rng::Rng;
#[cfg(feature = "pjrt")]
use crate::workload::reasoning::{generate, Episode};
use crate::workload::reasoning::TaskConfig;
#[cfg(feature = "pjrt")]
use crate::workload::Vocab;

/// Locate the artifacts directory (env override for tests).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("SEERATTN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

pub fn results_dir() -> PathBuf {
    let d = PathBuf::from("results");
    std::fs::create_dir_all(&d).ok();
    d
}

/// Load the runtime + trained model parameters (falls back to the init
/// checkpoint with a warning when no trained checkpoint exists).
#[cfg(feature = "pjrt")]
pub fn load_runtime_and_params(dir: &Path) -> Result<(Runtime, ParamStore)> {
    let rt = Runtime::load(dir)?;
    let trained = train::model_ckpt_path(dir);
    let path = if trained.exists() {
        trained
    } else {
        eprintln!("[harness] WARNING: no trained model at {}; using init weights",
                  trained.display());
        dir.join("model_init.bin")
    };
    let params = ParamStore::load(&path, &rt.manifest.params)?;
    Ok((rt, params))
}

/// Load gate parameters for a block size (distilled checkpoint preferred).
#[cfg(feature = "pjrt")]
pub fn load_gates(rt: &Runtime, dir: &Path, block_size: usize) -> Result<ParamStore> {
    let distilled = train::gate_ckpt_path(dir, block_size);
    let path = if distilled.exists() {
        distilled
    } else {
        eprintln!("[harness] WARNING: no distilled gate at {}; using init gate",
                  distilled.display());
        dir.join("gate_init.bin")
    };
    ParamStore::load(&path, &rt.manifest.gate_params)
}

/// Outcome of evaluating one (policy, task) configuration.
#[derive(Debug, Clone)]
pub struct EvalOutcome {
    pub n: usize,
    /// pass@1 over episodes (unanswered counts as wrong).
    pub accuracy: f64,
    pub answered_frac: f64,
    pub mean_gen_len: f64,
    pub mean_recall: Option<f64>,
    /// Mean activated tokens per step per KV head (Fig 9 accounting).
    pub mean_activated: Option<f64>,
    /// (context len, activated tokens) points across all steps (Fig 9a).
    pub activation_points: Vec<(usize, f64)>,
    /// Fraction of dense KV bytes touched.
    pub kv_touch_fraction: f64,
    pub wall_s: f64,
}

/// Evaluate `n` episodes of `task` on an engine (policy already set).
#[cfg(feature = "pjrt")]
pub fn eval_policy(engine: &mut Engine, task: TaskConfig, n: usize, seed: u64,
                   max_new: usize) -> Result<EvalOutcome> {
    let vocab = Vocab::default();
    let mut rng = Rng::new(seed);
    let episodes: Vec<Episode> =
        (0..n).map(|_| generate(&vocab, &task, &mut rng)).collect();
    let t0 = std::time::Instant::now();
    for (i, ep) in episodes.iter().enumerate() {
        engine.submit(Request::new(i as u64, ep.prompt.clone(), max_new));
    }
    let completions = engine.run_to_completion()?;
    let mut correct = 0usize;
    let mut answered = 0usize;
    let mut gen_len_sum = 0usize;
    let mut recall_sum = 0.0;
    let mut recall_n = 0usize;
    let mut act_sum = 0.0;
    let mut act_n = 0usize;
    let mut points = Vec::new();
    for c in &completions {
        let ep = &episodes[c.id as usize];
        match ep.score(&vocab, &c.generated) {
            Some(true) => {
                correct += 1;
                answered += 1;
            }
            Some(false) => answered += 1,
            None => {}
        }
        gen_len_sum += Episode::gen_len(&vocab, &c.generated);
        if let Some(r) = c.stats.mean_recall() {
            recall_sum += r;
            recall_n += 1;
        }
        if let Some(a) = c.stats.mean_activated() {
            act_sum += a;
            act_n += 1;
        }
        points.extend(c.stats.activated.iter().cloned());
    }
    let nf = completions.len().max(1) as f64;
    Ok(EvalOutcome {
        n: completions.len(),
        accuracy: correct as f64 / nf,
        answered_frac: answered as f64 / nf,
        mean_gen_len: gen_len_sum as f64 / nf,
        mean_recall: if recall_n > 0 { Some(recall_sum / recall_n as f64) } else { None },
        mean_activated: if act_n > 0 { Some(act_sum / act_n as f64) } else { None },
        activation_points: points,
        kv_touch_fraction: engine.metrics.kv_touch_fraction(),
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

/// Build a fresh engine for one configuration. Share the `Rc<Runtime>`
/// across engines to reuse the executable compile cache.
#[cfg(feature = "pjrt")]
pub fn build_engine(rt: &std::rc::Rc<Runtime>, dir: &Path,
                    ecfg: EngineConfig) -> Result<Engine> {
    let trained = train::model_ckpt_path(dir);
    let path = if trained.exists() { trained } else { dir.join("model_init.bin") };
    let params = ParamStore::load(&path, &rt.manifest.params)?;
    let gates = load_gates(rt, dir, ecfg.block_size)?;
    Engine::new(rt.clone(), params, gates, ecfg)
}

/// Max generation budget for a task inside the context window.
pub fn max_new_for(task: &TaskConfig, max_seq: usize) -> usize {
    let room = max_seq.saturating_sub(task.context_tokens() + 4);
    (task.target_tokens() * 3 + 16).min(room).min(96)
}

/// Ensure artifacts exist; tests use this to self-skip.
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

pub fn require_artifacts() -> Result<PathBuf> {
    let d = artifacts_dir();
    if d.join("manifest.json").exists() {
        Ok(d)
    } else {
        Err(anyhow!("artifacts not built; run `make artifacts`"))
    }
}
