//! Multi-hop variable-chain reasoning tasks.
//!
//! An episode's context is a shuffled list of assignment facts
//! (`var = var2 ;` links and `var = val ;` terminals) organised into
//! chains. The query names the head of one chain; the target generation
//! re-derives the chain hop by hop (each hop requires *retrieving* the
//! fact for the current variable from wherever it landed in the context —
//! long-range, content-addressed attention) and finishes with
//! `ANS <val> EOS`.
//!
//! Difficulty knobs mirror the paper's benchmark spread: `hops` (1 =
//! MATH-500-like, 3-4 = AIME-like) and `n_chains` (context length /
//! distractor density). Accuracy is exact (the emitted ANS value), and a
//! failed retrieval sends the generation wandering — the mechanism behind
//! the paper's Table 1 generation-length inflation.

use crate::util::rng::Rng;

/// Token-id layout within the model's 256-token vocabulary.
#[derive(Debug, Clone, Copy)]
pub struct Vocab {
    pub pad: i32,
    pub bos: i32,
    pub eos: i32,
    pub query: i32,
    pub eq: i32,
    pub sep: i32,
    pub arrow: i32,
    pub ans: i32,
    pub var0: i32,
    pub n_vars: i32,
    pub val0: i32,
    pub n_vals: i32,
}

impl Default for Vocab {
    fn default() -> Self {
        Vocab {
            pad: 0,
            bos: 1,
            eos: 2,
            query: 3,
            eq: 4,
            sep: 5,
            arrow: 6,
            ans: 7,
            var0: 16,
            n_vars: 150,
            val0: 170,
            n_vals: 60,
        }
    }
}

impl Vocab {
    pub fn var(&self, i: usize) -> i32 {
        assert!((i as i32) < self.n_vars);
        self.var0 + i as i32
    }

    pub fn val(&self, i: usize) -> i32 {
        assert!((i as i32) < self.n_vals);
        self.val0 + i as i32
    }

    pub fn is_val(&self, t: i32) -> bool {
        t >= self.val0 && t < self.val0 + self.n_vals
    }

    pub fn is_var(&self, t: i32) -> bool {
        t >= self.var0 && t < self.var0 + self.n_vars
    }
}

/// Episode generation parameters.
#[derive(Debug, Clone, Copy)]
pub struct TaskConfig {
    /// Chain length of the queried chain (number of lookups).
    pub hops: usize,
    /// Total chains in the context (all of length `hops`); one is queried,
    /// the rest are distractors.
    pub n_chains: usize,
}

impl TaskConfig {
    /// "MATH-500-like": single-hop, moderate context.
    pub fn easy() -> TaskConfig {
        TaskConfig { hops: 1, n_chains: 24 }
    }

    /// "AIME-like": multi-hop, dense context.
    pub fn hard() -> TaskConfig {
        TaskConfig { hops: 3, n_chains: 24 }
    }

    pub fn context_tokens(&self) -> usize {
        // BOS + facts * 4 + query (3 tokens)
        1 + self.n_chains * self.hops * 4 + 3
    }

    pub fn target_tokens(&self) -> usize {
        // hops * 4 (fact re-derivations) + ANS val EOS
        self.hops * 4 + 3
    }
}

/// One generated episode.
#[derive(Debug, Clone)]
pub struct Episode {
    /// BOS + facts + "Q head ->" (what the engine prefills).
    pub prompt: Vec<i32>,
    /// The ideal continuation (used as LM target during pretraining).
    pub target: Vec<i32>,
    /// Correct final value token.
    pub answer: i32,
    pub cfg: TaskConfig,
}

impl Episode {
    /// Full training sequence = prompt ++ target.
    pub fn full(&self) -> Vec<i32> {
        let mut v = self.prompt.clone();
        v.extend_from_slice(&self.target);
        v
    }

    /// Score a generated continuation: Some(true/false) once an ANS token
    /// pair appears, None if generation never answered.
    pub fn score(&self, vocab: &Vocab, generated: &[i32]) -> Option<bool> {
        let mut it = generated.iter().peekable();
        while let Some(&t) = it.next() {
            if t == vocab.ans {
                if let Some(&&v) = it.peek() {
                    return Some(v == self.answer);
                }
                return Some(false);
            }
        }
        None
    }

    /// Generation length until (and including) EOS, or the full length.
    pub fn gen_len(vocab: &Vocab, generated: &[i32]) -> usize {
        for (i, &t) in generated.iter().enumerate() {
            if t == vocab.eos {
                return i + 1;
            }
        }
        generated.len()
    }
}

/// Generate one episode. All chains have `cfg.hops` links; variables are
/// globally unique so resolution is a function.
pub fn generate(vocab: &Vocab, cfg: &TaskConfig, rng: &mut Rng) -> Episode {
    let vars_needed = cfg.n_chains * (cfg.hops + 1);
    assert!(
        vars_needed <= vocab.n_vars as usize,
        "need {vars_needed} vars, have {}",
        vocab.n_vars
    );
    let var_ids = rng.sample_distinct(vocab.n_vars as usize, vars_needed);
    let mut facts: Vec<[i32; 4]> = Vec::new();
    let mut chains: Vec<Vec<i32>> = Vec::new();
    for c in 0..cfg.n_chains {
        // chain c: v0 <- v1 <- ... <- v_{hops-1} <- value
        let vs: Vec<i32> = (0..=cfg.hops)
            .map(|i| vocab.var(var_ids[c * (cfg.hops + 1) + i]))
            .collect();
        let value = vocab.val(rng.below(vocab.n_vals as usize));
        let mut chain_tokens = Vec::new();
        for i in 0..cfg.hops {
            let rhs = if i + 1 < cfg.hops { vs[i + 1] } else { value };
            facts.push([vs[i], vocab.eq, rhs, vocab.sep]);
            chain_tokens.push(vs[i]);
        }
        chain_tokens.push(value);
        chains.push(chain_tokens);
    }
    rng.shuffle(&mut facts);

    let queried = rng.below(cfg.n_chains);
    let chain = &chains[queried];
    let head = chain[0];
    let answer = *chain.last().unwrap();

    let mut prompt = vec![vocab.bos];
    for f in &facts {
        prompt.extend_from_slice(f);
    }
    prompt.extend_from_slice(&[vocab.query, head, vocab.arrow]);

    // Target: re-derive each hop ("cur = next ;"), then ANS value EOS.
    let mut target = Vec::new();
    for i in 0..cfg.hops {
        target.extend_from_slice(&[chain[i], vocab.eq, chain[i + 1], vocab.sep]);
    }
    target.extend_from_slice(&[vocab.ans, answer, vocab.eos]);

    Episode { prompt, target, answer, cfg: *cfg }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn episode_structure() {
        let v = Vocab::default();
        let mut rng = Rng::new(0);
        let cfg = TaskConfig { hops: 3, n_chains: 10 };
        let ep = generate(&v, &cfg, &mut rng);
        assert_eq!(ep.prompt.len(), cfg.context_tokens());
        assert_eq!(ep.target.len(), cfg.target_tokens());
        assert_eq!(ep.prompt[0], v.bos);
        assert_eq!(ep.prompt[ep.prompt.len() - 3], v.query);
        assert_eq!(*ep.prompt.last().unwrap(), v.arrow);
        assert!(v.is_val(ep.answer));
        assert_eq!(*ep.target.last().unwrap(), v.eos);
        assert_eq!(ep.target[ep.target.len() - 2], ep.answer);
    }

    #[test]
    fn chain_is_resolvable_from_facts() {
        let v = Vocab::default();
        let mut rng = Rng::new(1);
        let cfg = TaskConfig { hops: 4, n_chains: 8 };
        let ep = generate(&v, &cfg, &mut rng);
        // Parse facts from prompt, resolve the query by lookup.
        let mut map = std::collections::HashMap::new();
        let body = &ep.prompt[1..ep.prompt.len() - 3];
        for f in body.chunks(4) {
            assert_eq!(f[1], v.eq);
            assert_eq!(f[3], v.sep);
            assert!(map.insert(f[0], f[2]).is_none(), "duplicate LHS");
        }
        let mut cur = ep.prompt[ep.prompt.len() - 2];
        let mut steps = 0;
        while v.is_var(cur) {
            cur = *map.get(&cur).expect("unresolvable var");
            steps += 1;
            assert!(steps <= cfg.hops);
        }
        assert_eq!(cur, ep.answer);
        assert_eq!(steps, cfg.hops);
    }

    #[test]
    fn scoring() {
        let v = Vocab::default();
        let mut rng = Rng::new(2);
        let ep = generate(&v, &TaskConfig::easy(), &mut rng);
        // Perfect continuation scores correct.
        assert_eq!(ep.score(&v, &ep.target), Some(true));
        // Wrong answer.
        let mut bad = ep.target.clone();
        let n = bad.len();
        bad[n - 2] = if ep.answer == v.val(0) { v.val(1) } else { v.val(0) };
        assert_eq!(ep.score(&v, &bad), Some(false));
        // Never answers.
        assert_eq!(ep.score(&v, &[v.sep, v.sep]), None);
        // gen_len stops at EOS.
        assert_eq!(Episode::gen_len(&v, &ep.target), ep.target.len());
        assert_eq!(Episode::gen_len(&v, &[v.sep, v.eos, v.sep]), 2);
    }

    #[test]
    fn deterministic_given_seed() {
        let v = Vocab::default();
        let cfg = TaskConfig::hard();
        let a = generate(&v, &cfg, &mut Rng::new(7));
        let b = generate(&v, &cfg, &mut Rng::new(7));
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.target, b.target);
    }

    #[test]
    fn fits_default_context() {
        // Default eval configs must fit the 512-token decode window.
        for cfg in [TaskConfig::easy(), TaskConfig::hard()] {
            assert!(cfg.context_tokens() + cfg.target_tokens() + 32 <= 512,
                    "{cfg:?}");
        }
    }
}
