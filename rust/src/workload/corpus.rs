//! Pretraining / distillation corpus: episodes packed into fixed-length
//! training sequences (the paper packs OpenR1-MATH-220K into 32k-token
//! sequences; we pack mixed-difficulty episodes into `seq_len`).

use super::reasoning::{generate, Episode, TaskConfig, Vocab};
use crate::util::rng::Rng;

/// One packed training sequence: token ids + per-position loss weights.
#[derive(Debug, Clone)]
pub struct Packed {
    pub ids: Vec<i32>,
    pub loss_w: Vec<f32>,
}

/// Mixture of task difficulties used for pretraining and distillation.
pub fn default_mixture() -> Vec<TaskConfig> {
    vec![
        TaskConfig { hops: 1, n_chains: 12 },
        TaskConfig { hops: 1, n_chains: 24 },
        TaskConfig { hops: 2, n_chains: 16 },
        TaskConfig { hops: 2, n_chains: 24 },
        TaskConfig { hops: 3, n_chains: 16 },
        TaskConfig { hops: 3, n_chains: 24 },
        TaskConfig { hops: 4, n_chains: 18 },
    ]
}

/// Loss weight on context (facts) tokens vs. reasoning (post-query)
/// tokens: contexts are random and unlearnable, the chain-of-thought is
/// the signal.
pub const CONTEXT_W: f32 = 0.1;
pub const REASONING_W: f32 = 1.0;

/// Fraction of packed items that are in-context copy tasks. Copy tasks
/// (a random segment followed by its exact repeat, loss on the repeat)
/// are the classic induction-head driver; the lookup episodes reuse the
/// same circuit, so mixing them in accelerates the substrate model's
/// retrieval ability dramatically at this scale.
pub const COPY_FRAC: f64 = 0.4;

/// One in-context copy item: BOS + segment (context weight) then the
/// segment again + EOS (full weight).
fn copy_item(vocab: &Vocab, rng: &mut Rng) -> (Vec<i32>, Vec<i32>) {
    let len = rng.range(24, 48);
    let seg: Vec<i32> = (0..len)
        .map(|_| {
            if rng.bool(0.7) {
                vocab.var(rng.below(vocab.n_vars as usize))
            } else {
                vocab.val(rng.below(vocab.n_vals as usize))
            }
        })
        .collect();
    let mut prompt = vec![vocab.bos];
    prompt.extend_from_slice(&seg);
    let mut target = seg;
    target.push(vocab.eos);
    (prompt, target)
}

/// Pack episodes into a sequence of exactly `seq_len` tokens (PAD-filled,
/// PAD positions get zero loss weight).
pub fn pack_sequence(vocab: &Vocab, mixture: &[TaskConfig], seq_len: usize,
                     rng: &mut Rng) -> Packed {
    let mut ids = Vec::with_capacity(seq_len);
    let mut loss_w = Vec::with_capacity(seq_len);
    loop {
        let (prompt, target) = if rng.bool(COPY_FRAC) {
            copy_item(vocab, rng)
        } else {
            let cfg = *rng.choose(mixture);
            let ep: Episode = generate(vocab, &cfg, rng);
            (ep.prompt, ep.target)
        };
        let total = prompt.len() + target.len();
        if ids.len() + total > seq_len {
            break;
        }
        for &t in &prompt {
            ids.push(t);
            loss_w.push(CONTEXT_W);
        }
        for &t in &target {
            ids.push(t);
            loss_w.push(REASONING_W);
        }
        if ids.len() + 64 > seq_len {
            break; // no small-enough item will fit; stop trying
        }
    }
    while ids.len() < seq_len {
        ids.push(vocab.pad);
        loss_w.push(0.0);
    }
    Packed { ids, loss_w }
}

/// A batch of packed sequences, flattened row-major [batch, seq_len].
pub fn pack_batch(vocab: &Vocab, mixture: &[TaskConfig], batch: usize,
                  seq_len: usize, rng: &mut Rng) -> (Vec<i32>, Vec<f32>) {
    let mut ids = Vec::with_capacity(batch * seq_len);
    let mut ws = Vec::with_capacity(batch * seq_len);
    for _ in 0..batch {
        let p = pack_sequence(vocab, mixture, seq_len, rng);
        ids.extend_from_slice(&p.ids);
        ws.extend_from_slice(&p.loss_w);
    }
    (ids, ws)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_exact_length_and_padding() {
        let v = Vocab::default();
        let mut rng = Rng::new(0);
        let p = pack_sequence(&v, &default_mixture(), 512, &mut rng);
        assert_eq!(p.ids.len(), 512);
        assert_eq!(p.loss_w.len(), 512);
        // Padding suffix has zero weights.
        let mut in_pad = false;
        for (t, w) in p.ids.iter().zip(&p.loss_w).rev() {
            if *t != v.pad {
                in_pad = true; // reversed: once we leave the pad suffix
            }
            if !in_pad {
                assert_eq!(*w, 0.0);
            }
        }
        // At least one full episode packed.
        assert!(p.ids.iter().filter(|&&t| t == v.query).count() >= 1);
    }

    #[test]
    fn weights_match_regions() {
        let v = Vocab::default();
        let mut rng = Rng::new(1);
        let p = pack_sequence(&v, &[TaskConfig::easy()], 512, &mut rng);
        // Every ANS token is in the reasoning region -> weight 1.
        for (i, &t) in p.ids.iter().enumerate() {
            if t == v.ans {
                assert_eq!(p.loss_w[i], REASONING_W);
            }
            if t == v.bos {
                assert_eq!(p.loss_w[i], CONTEXT_W);
            }
        }
    }

    #[test]
    fn batch_shapes() {
        let v = Vocab::default();
        let mut rng = Rng::new(2);
        let (ids, ws) = pack_batch(&v, &default_mixture(), 4, 256, &mut rng);
        assert_eq!(ids.len(), 4 * 256);
        assert_eq!(ws.len(), 4 * 256);
    }

    #[test]
    fn episodes_fit_training_window() {
        for cfg in default_mixture() {
            assert!(cfg.context_tokens() + cfg.target_tokens() < 512, "{cfg:?}");
        }
    }
}
