//! Serving trace generation: Poisson arrivals over a task mixture, for
//! the end-to-end serving benchmark (latency/throughput under load).

use super::reasoning::{generate, Episode, TaskConfig, Vocab};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct TracedRequest {
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    pub episode: Episode,
    pub max_new: usize,
}

/// Generate `n` requests with exponential inter-arrival gaps at `rate_rps`
/// requests/second, drawing tasks uniformly from `mixture`.
pub fn poisson_trace(vocab: &Vocab, mixture: &[TaskConfig], n: usize,
                     rate_rps: f64, max_new: usize, rng: &mut Rng)
                     -> Vec<TracedRequest> {
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        t += rng.exp(rate_rps);
        let cfg = *rng.choose(mixture);
        out.push(TracedRequest {
            arrival_s: t,
            episode: generate(vocab, &cfg, rng),
            max_new,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_monotone_and_rate_roughly_right() {
        let v = Vocab::default();
        let mut rng = Rng::new(0);
        let tr = poisson_trace(&v, &[TaskConfig::easy()], 500, 10.0, 32, &mut rng);
        assert_eq!(tr.len(), 500);
        for w in tr.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        let span = tr.last().unwrap().arrival_s;
        let rate = 500.0 / span;
        assert!((rate - 10.0).abs() < 2.0, "empirical rate {rate}");
    }

    #[test]
    fn deterministic() {
        let v = Vocab::default();
        let a = poisson_trace(&v, &[TaskConfig::hard()], 5, 1.0, 8, &mut Rng::new(3));
        let b = poisson_trace(&v, &[TaskConfig::hard()], 5, 1.0, 8, &mut Rng::new(3));
        assert_eq!(a[4].arrival_s, b[4].arrival_s);
        assert_eq!(a[4].episode.prompt, b[4].episode.prompt);
    }
}
