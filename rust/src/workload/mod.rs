//! Synthetic reasoning workload (the AIME/MATH-500/GPQA stand-in, see
//! DESIGN.md §1): multi-hop variable-chain resolution with exact scoring.

pub mod corpus;
pub mod reasoning;
pub mod trace;

pub use reasoning::{Episode, TaskConfig, Vocab};
