//! Serving metrics: latency histograms, throughput, sparsity counters.

use std::time::Duration;

use crate::util::stats::Series;

#[derive(Debug, Default)]
pub struct Metrics {
    pub ttft_s: Series,
    pub e2e_s: Series,
    pub decode_step_s: Series,
    pub prefill_s: Series,
    pub tokens_generated: u64,
    pub requests_completed: u64,
    pub kv_bytes_touched: u64,
    pub kv_bytes_dense_equiv: u64,
    wall_start: Option<std::time::Instant>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn start_clock(&mut self) {
        if self.wall_start.is_none() {
            self.wall_start = Some(std::time::Instant::now());
        }
    }

    pub fn record_completion(&mut self, ttft: Duration, e2e: Duration, tokens: usize) {
        self.ttft_s.push(ttft.as_secs_f64());
        self.e2e_s.push(e2e.as_secs_f64());
        self.tokens_generated += tokens as u64;
        self.requests_completed += 1;
    }

    /// Generated tokens per wall-clock second since start_clock().
    pub fn throughput_tps(&self) -> f64 {
        match self.wall_start {
            Some(t0) => self.tokens_generated as f64 / t0.elapsed().as_secs_f64().max(1e-9),
            None => 0.0,
        }
    }

    /// Fraction of dense KV traffic actually touched (the paper's I/O
    /// saving: 1 - sparsity).
    pub fn kv_touch_fraction(&self) -> f64 {
        if self.kv_bytes_dense_equiv == 0 {
            return 1.0;
        }
        self.kv_bytes_touched as f64 / self.kv_bytes_dense_equiv as f64
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} tokens={} tps={:.1}\n  ttft    {}\n  e2e     {}\n  decode  {}\n  kv-touch fraction {:.3}",
            self.requests_completed,
            self.tokens_generated,
            self.throughput_tps(),
            self.ttft_s.summary("s"),
            self.e2e_s.summary("s"),
            self.decode_step_s.summary("s"),
            self.kv_touch_fraction(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_report() {
        let mut m = Metrics::new();
        m.start_clock();
        m.record_completion(Duration::from_millis(50), Duration::from_millis(500), 16);
        m.record_completion(Duration::from_millis(70), Duration::from_millis(700), 24);
        assert_eq!(m.requests_completed, 2);
        assert_eq!(m.tokens_generated, 40);
        assert!(m.throughput_tps() > 0.0);
        let r = m.report();
        assert!(r.contains("requests=2"));
    }

    #[test]
    fn touch_fraction_defaults_to_dense() {
        let m = Metrics::new();
        assert_eq!(m.kv_touch_fraction(), 1.0);
    }
}
