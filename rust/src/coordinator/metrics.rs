//! Serving metrics: latency histograms, throughput, sparsity counters,
//! and lifecycle-control counters (cancelled / deadline-expired).

use std::time::Duration;

use super::request::StopReason;
use crate::util::stats::Series;

#[derive(Debug, Default)]
pub struct Metrics {
    pub ttft_s: Series,
    pub e2e_s: Series,
    pub decode_step_s: Series,
    pub prefill_s: Series,
    pub tokens_generated: u64,
    pub requests_completed: u64,
    /// Requests stopped by [`StopReason::Cancelled`] (client disconnect,
    /// eviction, or explicit cancel). Not counted in
    /// `requests_completed`, and excluded from the latency series — a
    /// cancelled request was never served, so it must not skew TTFT/e2e
    /// percentiles. Its generated tokens still count as work done.
    pub requests_cancelled: u64,
    /// Requests stopped by [`StopReason::DeadlineExceeded`]; same
    /// accounting rules as `requests_cancelled`.
    pub requests_deadline_expired: u64,
    /// Requests stopped by [`StopReason::ResourceExhausted`] (preemption
    /// retry budget spent, or infeasible against the page pool); same
    /// accounting rules as `requests_cancelled`.
    pub requests_exhausted: u64,
    /// Times a request was preempted mid-decode and requeued (one
    /// request may count several times). Preemption is not terminal, so
    /// this is a churn gauge, not a request outcome.
    pub requests_preempted: u64,
    /// Peak KV pages in use at once on this engine.
    pub pages_peak: usize,
    pub kv_bytes_touched: u64,
    pub kv_bytes_dense_equiv: u64,
    /// Requests this shard pulled from other shards' overflow queues
    /// (work stealing; set by the shard thread at shutdown).
    pub requests_stolen: u64,
    /// Prefill chunk executions (one per engine step that did any
    /// prefill work). With monolithic prefill (`prefill_chunk = 0`) this
    /// equals the number of admission steps; with chunking it grows by
    /// `ceil(eff_len / chunk)` per long prompt.
    pub prefill_chunks: u64,
    /// Prompt tokens prefilled, summed over chunks. A preempted request
    /// that re-prefills counts its span again, so `prefill_tokens`
    /// versus the sum of admitted prompt lengths exposes re-prefill
    /// overhead.
    pub prefill_tokens: u64,
    /// Peak overflow-queue length observed at this shard.
    pub queue_peak: u64,
    /// Admissions that found at least one full prompt block in the
    /// prefix cache.
    pub prefix_hits: u64,
    /// Full prompt blocks spliced from the prefix cache instead of being
    /// prefilled (each one is a block of KV *and* gate work skipped —
    /// compare against `prefill_tokens` to see the saving).
    pub prefix_blocks_reused: u64,
    /// Cached prefix blocks evicted (LRU under the capacity cap or
    /// yielded back under page-pool pressure).
    pub prefix_evictions: u64,
    wall_start: Option<std::time::Instant>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn start_clock(&mut self) {
        if self.wall_start.is_none() {
            self.wall_start = Some(std::time::Instant::now());
        }
    }

    pub fn record_completion(&mut self, ttft: Duration, e2e: Duration,
                             tokens: usize, stop: StopReason) {
        match stop {
            StopReason::Cancelled => self.requests_cancelled += 1,
            StopReason::DeadlineExceeded => self.requests_deadline_expired += 1,
            StopReason::ResourceExhausted => self.requests_exhausted += 1,
            _ => {
                self.ttft_s.push(ttft.as_secs_f64());
                self.e2e_s.push(e2e.as_secs_f64());
                self.requests_completed += 1;
            }
        }
        self.tokens_generated += tokens as u64;
    }

    /// Fold another engine's metrics into this one (shard -> fleet).
    /// Latency series concatenate; counters add. The wall clock is *not*
    /// merged — fleet throughput is computed against the group's own
    /// clock (see [`GroupMetrics`]), since per-shard clocks overlap.
    pub fn merge_from(&mut self, other: &Metrics) {
        self.ttft_s.extend_from(&other.ttft_s);
        self.e2e_s.extend_from(&other.e2e_s);
        self.decode_step_s.extend_from(&other.decode_step_s);
        self.prefill_s.extend_from(&other.prefill_s);
        self.tokens_generated += other.tokens_generated;
        self.requests_completed += other.requests_completed;
        self.requests_cancelled += other.requests_cancelled;
        self.requests_deadline_expired += other.requests_deadline_expired;
        self.requests_exhausted += other.requests_exhausted;
        self.requests_preempted += other.requests_preempted;
        self.kv_bytes_touched += other.kv_bytes_touched;
        self.kv_bytes_dense_equiv += other.kv_bytes_dense_equiv;
        self.requests_stolen += other.requests_stolen;
        self.prefill_chunks += other.prefill_chunks;
        self.prefill_tokens += other.prefill_tokens;
        self.prefix_hits += other.prefix_hits;
        self.prefix_blocks_reused += other.prefix_blocks_reused;
        self.prefix_evictions += other.prefix_evictions;
        // A fleet's "peak queue" is the worst shard's, not a sum; same
        // for peak pages (per-shard pools are independent).
        self.queue_peak = self.queue_peak.max(other.queue_peak);
        self.pages_peak = self.pages_peak.max(other.pages_peak);
    }

    /// Generated tokens per wall-clock second since start_clock().
    pub fn throughput_tps(&self) -> f64 {
        match self.wall_start {
            Some(t0) => self.tokens_generated as f64 / t0.elapsed().as_secs_f64().max(1e-9),
            None => 0.0,
        }
    }

    /// Fraction of dense KV traffic actually touched (the paper's I/O
    /// saving: 1 - sparsity).
    pub fn kv_touch_fraction(&self) -> f64 {
        if self.kv_bytes_dense_equiv == 0 {
            return 1.0;
        }
        self.kv_bytes_touched as f64 / self.kv_bytes_dense_equiv as f64
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} tokens={} tps={:.1} cancelled={} deadline-expired={} preempted={} exhausted={} pages-peak={} prefill-chunks={} prefill-tokens={} prefix-hits={} prefix-blocks-reused={} prefix-evictions={}\n  ttft    {}\n  e2e     {}\n  decode  {}\n  kv-touch fraction {:.3}",
            self.requests_completed,
            self.tokens_generated,
            self.throughput_tps(),
            self.requests_cancelled,
            self.requests_deadline_expired,
            self.requests_preempted,
            self.requests_exhausted,
            self.pages_peak,
            self.prefill_chunks,
            self.prefill_tokens,
            self.prefix_hits,
            self.prefix_blocks_reused,
            self.prefix_evictions,
            self.ttft_s.summary("s"),
            self.e2e_s.summary("s"),
            self.decode_step_s.summary("s"),
            self.kv_touch_fraction(),
        )
    }
}

/// Front-end counters for one reactor thread: connection-set churn plus
/// the eventfd wakeups it consumed. Collected by the serve loop and
/// stitched into [`GroupMetrics::report`] at fleet teardown so a
/// multi-reactor run shows where accepts and evictions landed.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ReactorStats {
    /// Connections accepted (or adopted via accept-fd handoff) and
    /// registered with this reactor.
    pub conns_accepted: u64,
    /// Connections turned away with a structured "overloaded" reply
    /// because this reactor was at its connection cap.
    pub conns_rejected: u64,
    /// Connections dropped by this reactor: idle eviction or a write
    /// buffer over the slow-consumer cap.
    pub conns_evicted: u64,
    /// Accepted sockets lost to a setup failure (`set_nonblocking` or
    /// epoll registration) before they could carry a request. Counted so
    /// capacity accounting can't silently lie.
    pub conns_failed: u64,
    /// eventfd wakeups consumed (completion signals from shards plus
    /// handoff notifications from reactor 0).
    pub wakes: u64,
}

impl ReactorStats {
    pub fn merge_from(&mut self, other: &ReactorStats) {
        self.conns_accepted += other.conns_accepted;
        self.conns_rejected += other.conns_rejected;
        self.conns_evicted += other.conns_evicted;
        self.conns_failed += other.conns_failed;
        self.wakes += other.wakes;
    }
}

/// Shard-supervisor counters: crash/wedge detection and the recovery
/// work done on behalf of the requests a failing shard held. Kept by the
/// router (the supervisor runs on the polling side, not in shard
/// threads), stitched into [`GroupMetrics::report`] at shutdown.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ShardRestarts {
    /// Shard threads respawned after a panic.
    pub restarts: u64,
    /// Wedge-watchdog trips: heartbeat stalls past `wedge_timeout` that
    /// circuit-broke a live shard out of routing (each recovery when the
    /// heartbeat resumes clears the trip but not the count).
    pub wedges: u64,
    /// Requests rescued out of a dead shard's overflow queue and requeued
    /// to live shards.
    pub rescued_queued: u64,
    /// In-flight requests re-submitted with resume-replay after their
    /// shard died.
    pub rescued_inflight: u64,
    /// Requests terminated with `ResourceExhausted` because their rescue
    /// budget ran out, plus shards retired for good after exhausting
    /// `restart_limit`.
    pub give_ups: u64,
    /// Pages the dead shards' `MemoryPlan` ledgers still held after
    /// per-request reconciliation (leaked state only the crash knew
    /// about, zeroed so respawned shards start with a clean budget).
    pub pages_reclaimed: u64,
}

impl ShardRestarts {
    pub fn merge_from(&mut self, other: &ShardRestarts) {
        self.restarts += other.restarts;
        self.wedges += other.wedges;
        self.rescued_queued += other.rescued_queued;
        self.rescued_inflight += other.rescued_inflight;
        self.give_ups += other.give_ups;
        self.pages_reclaimed += other.pages_reclaimed;
    }

    pub fn is_quiet(&self) -> bool {
        *self == ShardRestarts::default()
    }
}

/// Aggregated serving metrics for an [`EngineGroup`]: the per-shard
/// [`Metrics`] snapshots plus the group's own wall-clock span, from which
/// fleet throughput and latency percentiles are derived.
///
/// [`EngineGroup`]: super::shard::EngineGroup
#[derive(Debug, Default)]
pub struct GroupMetrics {
    /// One snapshot per shard, indexed by shard id. A shard that
    /// panicked and was respawned contributes its replacement
    /// incarnations' metrics (merged in at shutdown); the crashed
    /// incarnation's own counters died with it.
    pub shards: Vec<Metrics>,
    /// Group wall-clock seconds from first submit to shutdown.
    pub wall_s: f64,
    /// Shards at least one of whose thread incarnations panicked instead
    /// of shutting down cleanly (deduplicated); the supervisor rescues
    /// their requests, but the crashed incarnation's metrics are lost.
    pub panicked: Vec<usize>,
    /// Requests the router rejected under admission backpressure (every
    /// shard at `batch + queue_depth` load).
    pub rejected: u64,
    /// Requests the router deferred because no shard's page budget could
    /// fit their projected peak KV demand (count headroom existed;
    /// memory, not compute, was the bottleneck — a retry can succeed).
    pub deferred: u64,
    /// The configured per-shard overflow-queue bound the rejections were
    /// measured against.
    pub queue_depth: usize,
    /// One entry per front-end reactor thread, indexed by reactor id.
    /// Empty when the group was driven without a socket front end (trace
    /// harness, unit tests).
    pub reactors: Vec<ReactorStats>,
    /// Shard-supervisor activity (crash respawns, wedge trips, request
    /// rescues). All-zero on a run with no shard failures.
    pub supervision: ShardRestarts,
}

impl GroupMetrics {
    /// Merge all shard snapshots into one fleet-level [`Metrics`].
    pub fn fleet(&self) -> Metrics {
        let mut m = Metrics::new();
        for s in &self.shards {
            m.merge_from(s);
        }
        m
    }

    /// Generated tokens per wall-clock second across the whole fleet.
    pub fn fleet_tps(&self) -> f64 {
        let tokens: u64 = self.shards.iter().map(|s| s.tokens_generated).sum();
        tokens as f64 / self.wall_s.max(1e-9)
    }

    /// Per-shard + fleet report: request counts, cancelled /
    /// deadline-expired counts, throughput, and TTFT / e2e p50/p95/p99.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for &i in &self.panicked {
            out.push_str(&format!("shard {i}: PANICKED (metrics lost)\n"));
        }
        for (i, s) in self.shards.iter().enumerate() {
            out.push_str(&format!(
                "shard {i}: requests={} tokens={} cancelled={} deadline={} \
                 preempted={} exhausted={} stolen={} queue-peak={} \
                 pages-peak={} \
                 ttft p50={:.4}s p95={:.4}s p99={:.4}s \
                 e2e p50={:.4}s p95={:.4}s\n",
                s.requests_completed,
                s.tokens_generated,
                s.requests_cancelled,
                s.requests_deadline_expired,
                s.requests_preempted,
                s.requests_exhausted,
                s.requests_stolen,
                s.queue_peak,
                s.pages_peak,
                s.ttft_s.median(),
                s.ttft_s.percentile(95.0),
                s.ttft_s.percentile(99.0),
                s.e2e_s.median(),
                s.e2e_s.percentile(95.0),
            ));
        }
        for (r, s) in self.reactors.iter().enumerate() {
            out.push_str(&format!(
                "reactor {r}: accepted={} rejected={} evicted={} failed={} \
                 wakes={}\n",
                s.conns_accepted,
                s.conns_rejected,
                s.conns_evicted,
                s.conns_failed,
                s.wakes,
            ));
        }
        if !self.supervision.is_quiet() {
            let s = &self.supervision;
            out.push_str(&format!(
                "supervisor: restarts={} wedges={} rescued-queued={} \
                 rescued-inflight={} give-ups={} pages-reclaimed={}\n",
                s.restarts,
                s.wedges,
                s.rescued_queued,
                s.rescued_inflight,
                s.give_ups,
                s.pages_reclaimed,
            ));
        }
        let f = self.fleet();
        out.push_str(&format!(
            "fleet ({} shards): requests={} tokens={} tps={:.1} \
             rejected={} deferred={} cancelled={} deadline-expired={} \
             preempted={} exhausted={} stolen={} \
             queue-depth={} pages-peak={} \
             prefix-hits={} prefix-blocks-reused={} prefix-evictions={} \
             ttft p50={:.4}s p95={:.4}s p99={:.4}s \
             e2e p50={:.4}s p95={:.4}s p99={:.4}s kv-touch {:.3}",
            self.shards.len(),
            f.requests_completed,
            f.tokens_generated,
            self.fleet_tps(),
            self.rejected,
            self.deferred,
            f.requests_cancelled,
            f.requests_deadline_expired,
            f.requests_preempted,
            f.requests_exhausted,
            f.requests_stolen,
            self.queue_depth,
            f.pages_peak,
            f.prefix_hits,
            f.prefix_blocks_reused,
            f.prefix_evictions,
            f.ttft_s.median(),
            f.ttft_s.percentile(95.0),
            f.ttft_s.percentile(99.0),
            f.e2e_s.median(),
            f.e2e_s.percentile(95.0),
            f.e2e_s.percentile(99.0),
            f.kv_touch_fraction(),
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_report() {
        let mut m = Metrics::new();
        m.start_clock();
        m.record_completion(Duration::from_millis(50), Duration::from_millis(500),
                            16, StopReason::Eos);
        m.record_completion(Duration::from_millis(70), Duration::from_millis(700),
                            24, StopReason::MaxNewTokens);
        assert_eq!(m.requests_completed, 2);
        assert_eq!(m.tokens_generated, 40);
        assert!(m.throughput_tps() > 0.0);
        let r = m.report();
        assert!(r.contains("requests=2"));
        assert!(r.contains("cancelled=0"));
    }

    #[test]
    fn control_stops_count_separately_and_skip_latency_series() {
        let mut m = Metrics::new();
        m.record_completion(Duration::from_millis(10), Duration::from_millis(100),
                            8, StopReason::Eos);
        // Cancelled / expired requests: counted, tokens accounted as work
        // done, but excluded from the served-latency percentiles.
        m.record_completion(Duration::from_millis(5), Duration::from_millis(50),
                            3, StopReason::Cancelled);
        m.record_completion(Duration::ZERO, Duration::from_millis(70),
                            0, StopReason::DeadlineExceeded);
        assert_eq!(m.requests_completed, 1);
        assert_eq!(m.requests_cancelled, 1);
        assert_eq!(m.requests_deadline_expired, 1);
        assert_eq!(m.tokens_generated, 11);
        assert_eq!(m.ttft_s.len(), 1, "control stops must not skew TTFT");
        assert_eq!(m.e2e_s.len(), 1);

        let mut other = Metrics::new();
        other.record_completion(Duration::ZERO, Duration::from_millis(30),
                                2, StopReason::Cancelled);
        m.merge_from(&other);
        assert_eq!(m.requests_cancelled, 2, "cancel counts add on merge");
        assert_eq!(m.requests_deadline_expired, 1);

        let mut g = GroupMetrics { queue_depth: 4, ..Default::default() };
        g.shards.push(m);
        let r = g.report();
        assert!(r.contains("cancelled=2"), "{r}");
        assert!(r.contains("deadline-expired=1"), "{r}");
        assert!(r.contains("ttft p50="), "{r}");
        assert!(r.contains("p99="), "{r}");
    }

    #[test]
    fn touch_fraction_defaults_to_dense() {
        let m = Metrics::new();
        assert_eq!(m.kv_touch_fraction(), 1.0);
    }

    #[test]
    fn exhausted_requests_skip_latency_series_and_counters_merge() {
        let mut m = Metrics::new();
        m.record_completion(Duration::from_millis(10), Duration::from_millis(100),
                            8, StopReason::Eos);
        m.record_completion(Duration::from_millis(5), Duration::from_millis(90),
                            3, StopReason::ResourceExhausted);
        assert_eq!(m.requests_completed, 1);
        assert_eq!(m.requests_exhausted, 1);
        assert_eq!(m.tokens_generated, 11, "partial tokens still count as work");
        assert_eq!(m.ttft_s.len(), 1, "exhausted must not skew TTFT");
        assert_eq!(m.e2e_s.len(), 1);

        m.requests_preempted = 2;
        m.pages_peak = 9;
        let mut other = Metrics::new();
        other.record_completion(Duration::ZERO, Duration::from_millis(40),
                                1, StopReason::ResourceExhausted);
        other.requests_preempted = 3;
        other.pages_peak = 12;
        m.merge_from(&other);
        assert_eq!(m.requests_exhausted, 2, "exhausted counts add on merge");
        assert_eq!(m.requests_preempted, 5, "preempt counts add on merge");
        assert_eq!(m.pages_peak, 12, "fleet pages peak is the worst shard's");

        let r = m.report();
        assert!(r.contains("preempted=5"), "{r}");
        assert!(r.contains("exhausted=2"), "{r}");
        assert!(r.contains("pages-peak=12"), "{r}");

        let mut g = GroupMetrics { deferred: 4, ..Default::default() };
        g.shards.push(m);
        let r = g.report();
        assert!(r.contains("deferred=4"), "{r}");
        assert!(r.contains("preempted=5"), "{r}");
        assert!(r.contains("exhausted=2"), "{r}");
        assert!(r.contains("pages-peak=12"), "{r}");
    }

    #[test]
    fn prefix_counters_add_on_merge_and_reach_both_reports() {
        let mut a = Metrics::new();
        a.prefix_hits = 2;
        a.prefix_blocks_reused = 7;
        a.prefix_evictions = 1;
        let mut b = Metrics::new();
        b.prefix_hits = 3;
        b.prefix_blocks_reused = 4;
        b.prefix_evictions = 2;
        a.merge_from(&b);
        assert_eq!(a.prefix_hits, 5);
        assert_eq!(a.prefix_blocks_reused, 11);
        assert_eq!(a.prefix_evictions, 3);
        let r = a.report();
        assert!(r.contains("prefix-hits=5"), "{r}");
        assert!(r.contains("prefix-blocks-reused=11"), "{r}");
        assert!(r.contains("prefix-evictions=3"), "{r}");
        let mut g = GroupMetrics::default();
        g.shards.push(a);
        let r = g.report();
        assert!(r.contains("prefix-hits=5"), "{r}");
        assert!(r.contains("prefix-blocks-reused=11"), "{r}");
        assert!(r.contains("prefix-evictions=3"), "{r}");
    }

    #[test]
    fn merge_concatenates_series_and_adds_counters() {
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.record_completion(Duration::from_millis(10), Duration::from_millis(100),
                            4, StopReason::Eos);
        b.record_completion(Duration::from_millis(30), Duration::from_millis(300),
                            6, StopReason::Eos);
        b.kv_bytes_touched = 8;
        b.kv_bytes_dense_equiv = 16;
        a.requests_stolen = 2;
        a.queue_peak = 7;
        b.requests_stolen = 3;
        b.queue_peak = 4;
        a.merge_from(&b);
        assert_eq!(a.requests_completed, 2);
        assert_eq!(a.tokens_generated, 10);
        assert_eq!(a.ttft_s.len(), 2);
        assert_eq!(a.kv_bytes_touched, 8);
        assert_eq!(a.requests_stolen, 5, "steal counts add");
        assert_eq!(a.queue_peak, 7, "fleet queue peak is the worst shard's");
        assert!((a.ttft_s.mean() - 0.02).abs() < 1e-9);
    }

    #[test]
    fn reactor_stats_merge_and_reach_the_group_report() {
        let mut a = ReactorStats {
            conns_accepted: 3,
            conns_rejected: 1,
            conns_evicted: 0,
            conns_failed: 1,
            wakes: 7,
        };
        let b = ReactorStats {
            conns_accepted: 2,
            conns_rejected: 0,
            conns_evicted: 2,
            conns_failed: 0,
            wakes: 5,
        };
        a.merge_from(&b);
        assert_eq!(a.conns_accepted, 5);
        assert_eq!(a.conns_evicted, 2);
        assert_eq!(a.conns_failed, 1);
        assert_eq!(a.wakes, 12);

        let mut g = GroupMetrics::default();
        g.reactors.push(a);
        g.reactors.push(b);
        let r = g.report();
        assert!(r.contains("reactor 0: accepted=5"), "{r}");
        assert!(r.contains("failed=1"), "{r}");
        assert!(r.contains("wakes=12"), "{r}");
        assert!(r.contains("reactor 1: accepted=2"), "{r}");

        // A trace-harness group reports no reactor lines at all.
        let g = GroupMetrics::default();
        assert!(!g.report().contains("reactor"), "{}", g.report());
    }

    #[test]
    fn supervision_counters_merge_and_only_report_when_active() {
        // A quiet run must not grow a supervisor line in the report.
        let quiet = GroupMetrics::default();
        assert!(quiet.supervision.is_quiet());
        assert!(!quiet.report().contains("supervisor:"), "{}", quiet.report());

        let mut a = ShardRestarts {
            restarts: 1,
            wedges: 0,
            rescued_queued: 3,
            rescued_inflight: 2,
            give_ups: 0,
            pages_reclaimed: 5,
        };
        let b = ShardRestarts { wedges: 2, give_ups: 1, ..Default::default() };
        a.merge_from(&b);
        assert_eq!(a.restarts, 1);
        assert_eq!(a.wedges, 2);
        assert_eq!(a.rescued_queued, 3);
        assert_eq!(a.rescued_inflight, 2);
        assert_eq!(a.give_ups, 1);
        assert_eq!(a.pages_reclaimed, 5);
        assert!(!a.is_quiet());

        let g = GroupMetrics { supervision: a, ..Default::default() };
        let r = g.report();
        assert!(r.contains("supervisor: restarts=1"), "{r}");
        assert!(r.contains("wedges=2"), "{r}");
        assert!(r.contains("rescued-queued=3"), "{r}");
        assert!(r.contains("rescued-inflight=2"), "{r}");
        assert!(r.contains("give-ups=1"), "{r}");
        assert!(r.contains("pages-reclaimed=5"), "{r}");
    }

    #[test]
    fn group_metrics_fleet_percentiles_span_shards() {
        let mut g = GroupMetrics::default();
        for shard in 0..3 {
            let mut m = Metrics::new();
            for k in 0..4 {
                let ms = 10 * (shard * 4 + k + 1);
                m.record_completion(
                    Duration::from_millis(ms),
                    Duration::from_millis(10 * ms),
                    3,
                    StopReason::Eos,
                );
            }
            g.shards.push(m);
        }
        g.wall_s = 2.0;
        g.rejected = 5;
        g.queue_depth = 8;
        let f = g.fleet();
        assert_eq!(f.requests_completed, 12);
        assert_eq!(f.tokens_generated, 36);
        // Samples 10ms..120ms across shards: fleet median = 65ms.
        assert!((f.ttft_s.median() - 0.065).abs() < 1e-9);
        assert!((g.fleet_tps() - 18.0).abs() < 1e-9);
        let r = g.report();
        assert!(r.contains("shard 0"));
        assert!(r.contains("fleet (3 shards)"));
        assert!(r.contains("rejected=5"));
        assert!(r.contains("queue-depth=8"));
    }
}
