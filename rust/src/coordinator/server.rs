//! Event-driven TCP JSON-lines serving front-end over a sharded
//! [`EngineGroup`].
//!
//! Protocol (one JSON object per line):
//!   request:  {"id": 1, "prompt": [tok, ...], "max_new": 32}
//!             optional: "stream": true|false (overrides the server
//!             default), "deadline_ms": N (per-request deadline from
//!             arrival; overrides --deadline-ms), "priority":
//!             "interactive"|"batch" (scheduling class; overrides
//!             --default-priority)
//!   response: {"id": 1, "generated": [tok, ...], "stop": "eos",
//!              "ttft_ms": 12.3, "e2e_ms": 45.6}
//!   deltas:   streaming requests additionally get one
//!             {"delta": [tok], "id": 1, "index": K} frame per generated
//!             token *before* the terminal response line; the
//!             concatenated deltas equal the final "generated" array
//!             byte-for-byte (pinned by the streaming-parity test).
//!             A streaming request preempted mid-decode gets one
//!             {"id": 1, "event": "preempted"} frame; its delta stream
//!             resumes at the next index after re-admission (no token is
//!             repeated or lost).
//!   errors:   {"error": "..."} (parse) / {"id": N, "error": "..."}
//!             (per-request: prompt too long, overloaded). Backpressure
//!             errors additionally carry "retry_after_ms": N — when the
//!             router *deferred* the request for KV page headroom the
//!             hint is its configured retry window; a capacity
//!             rejection uses a short fixed hint.
//!
//! "stop" may also be "cancelled" (the client went away mid-decode),
//! "deadline" (the per-request deadline expired), or
//! "resource_exhausted" (preempted for memory and out of retry budget);
//! all carry whatever was generated up to that point.
//!
//! The front-end is a fleet of **reactor threads** over raw epoll (see
//! [`super::reactor`]): each reactor owns a disjoint set of connections
//! and drives non-blocking accept, reads, writes, and engine-completion
//! fan-out over per-connection state machines with
//! partial-read/partial-write buffers. [`ServeConfig::reactors`] sets
//! the fleet size (default 1 — the original single-threaded shape; 0 =
//! auto from the core count). With N > 1 each reactor prefers its own
//! `SO_REUSEPORT` listener (the kernel spreads accepts), falling back to
//! an accept-handoff channel from reactor 0 when the socket option is
//! unavailable or the caller pre-bound a single listener ([`serve_on`]).
//! Completion delivery is wakeup-driven: every reactor parks in
//! `epoll_wait` on an [`WakeFd`] eventfd that the shard fleet signals
//! after each event send ([`EngineGroup::register_wake`]), so an idle
//! reactor blocks indefinitely yet sees tokens at syscall latency — no
//! completion-poll tick. Request ids are partitioned by lane
//! (`id % reactors`), so each completion flows back to the reactor that
//! owns its connection. Compared to the thread-per-connection design
//! this caps front-end cost at N threads regardless of connection count
//! and makes hard limits enforceable:
//!
//! - **connection cap** (`max_conns`): excess clients get a structured
//!   error reply and are closed immediately — no unbounded thread spawn.
//! - **idle timeout** (`idle_timeout`): a connection with no in-flight
//!   work and no *completed request line* inside the window is evicted
//!   with a structured goodbye. Raw bytes do not refresh the clock, so
//!   a slow-loris dripping a partial line cannot hold a slot.
//! - **admission backpressure**: when the router reports every shard at
//!   `batch + queue_depth` load, the request is answered with an
//!   `overloaded` error instead of queueing unboundedly.
//! - **cancel propagation**: a connection that goes away — read-side
//!   EOF, hard socket error, slow-consumer drop, or eviction — has its
//!   in-flight requests *cancelled* at the owning shard instead of
//!   orphaning the decode: the engine frees the slot and KV pages at its
//!   next step boundary. (Read-side EOF therefore means "client is
//!   done": the cancelled partial replies still flush on the write half,
//!   but EOF no longer lets a departed client's decode run to
//!   completion.)
//! - **streaming backpressure**: delta frames accumulate (coalesce) in
//!   the bounded per-connection write buffer and drain under EPOLLOUT; a
//!   reader that falls [`MAX_WR_BYTES`] behind is dropped — which, per
//!   the above, cancels its in-flight decodes. Never unbounded.
//!
//! Ids are rewritten internally so concurrent clients cannot collide.
//! (The offline vendor set has no tokio; epoll + std::net provides the
//! same architecture.)
//!
//! **Graceful drain** ([`ServeConfig::drain_on_signal`]): `SIGTERM`
//! flips the fleet into *draining* instead of killing it. The handler
//! is a single async-signal-safe eventfd write; reactor 0 has that
//! process-global fd in its epoll set (token [`DRAIN`]) and broadcasts
//! the transition. Draining reactors stop accepting (listeners
//! deregistered, handed-off sockets get a structured refusal), reject
//! new request lines with a structured `draining` error, send a goodbye
//! to idle connections, let every in-flight request run to its normal
//! completion (deltas included), and exit through the ordinary
//! success path — final metrics report printed, exit code 0, zero
//! accepted requests dropped.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, FromRawFd};
use std::sync::atomic::{AtomicBool, AtomicI32, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::metrics::ReactorStats;
use super::reactor::{Event, Interest, Reactor, WakeFd};
use super::request::{Completion, Priority, Request};
use super::shard::{EngineGroup, GroupEvent, SubmitOutcome};
use super::DecodeEngine;
use crate::util::json::Json;

/// Reactor token reserved for the listener (when this reactor owns one).
const LISTENER: u64 = 0;

/// Reactor token reserved for the completion/handoff wake eventfd.
const WAKER: u64 = 1;

/// Reactor token reserved for the process-global SIGTERM drain eventfd
/// (registered by reactor 0 only, and only when
/// [`ServeConfig::drain_on_signal`] is set).
const DRAIN: u64 = 2;

/// Connection tokens start here.
const FIRST_CONN: u64 = 3;

/// A request line longer than this (no newline seen yet) is answered
/// with an error and the connection closed — a reasonable bound for a
/// token-id array protocol, and a guard against memory exhaustion.
const MAX_LINE_BYTES: usize = 1 << 20;

/// Pending-reply bytes beyond this mean the client is not draining its
/// socket; the connection is dropped rather than buffering without
/// bound (the blocking write this design replaced applied the same
/// pressure by stalling the writer).
const MAX_WR_BYTES: usize = 8 << 20;

/// Front-end limits; `Default` gives production-ish values, tests
/// override.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Hard cap on concurrently open connections; excess accepts get a
    /// structured error reply and an immediate close.
    pub max_conns: usize,
    /// Connections with no in-flight work and no traffic for this long
    /// are evicted (structured goodbye, then close).
    pub idle_timeout: Duration,
    /// Stop after this many completions have been collected (tests bind
    /// port 0 and set a limit); `None` serves forever.
    pub limit: Option<usize>,
    /// Stream token deltas for every request unless it says
    /// `"stream": false` (CLI `--stream`). Off by default: requests
    /// opt in with `"stream": true`.
    pub stream_by_default: bool,
    /// Server-imposed default deadline applied to every request that
    /// does not carry its own `deadline_ms` (CLI `--deadline-ms`);
    /// `None` = unbounded.
    pub deadline: Option<Duration>,
    /// Scheduling class for requests that carry no `"priority"` field
    /// (CLI `--default-priority`).
    pub default_priority: Priority,
    /// Front-end reactor threads (CLI `--reactors`). `0` = auto: one
    /// reactor per ~4 cores, clamped to `[1, 8]`. The effective count is
    /// additionally clamped to the group's lane count
    /// ([`super::shard::GroupConfig::lanes`]) — each reactor needs a
    /// completion lane of its own.
    pub reactors: usize,
    /// Install a `SIGTERM` handler that gracefully drains the fleet
    /// instead of letting the default disposition kill the process:
    /// stop accepting, finish in-flight work, goodbye idle clients,
    /// exit 0 with the final report. Off by default — libraries must
    /// not hijack process signal dispositions; the CLI opts in.
    pub drain_on_signal: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_conns: 256,
            idle_timeout: Duration::from_secs(30),
            limit: None,
            stream_by_default: false,
            deadline: None,
            default_priority: Priority::default(),
            reactors: 1,
            drain_on_signal: false,
        }
    }
}

/// Resolve the `reactors` knob against the machine: `0` = auto — one
/// reactor per ~4 cores (the front end only parses and frames; shard
/// threads should get the bulk), clamped to `[1, 8]`. An explicit
/// request is honoured as-is. `main.rs` uses this to size
/// [`super::shard::GroupConfig::lanes`] before building the group.
pub fn resolve_reactors(requested: usize) -> usize {
    if requested != 0 {
        return requested;
    }
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    (cores / 4).clamp(1, 8)
}

/// One parsed request line: the request itself plus the per-request
/// protocol options that belong to the front-end, not the engine.
pub struct WireRequest {
    pub req: Request,
    /// `"stream"` field: `Some` overrides
    /// [`ServeConfig::stream_by_default`].
    pub stream: Option<bool>,
    /// `"deadline_ms"` field: `Some` overrides [`ServeConfig::deadline`].
    pub deadline_ms: Option<u64>,
    /// `"priority"` field: `Some` overrides
    /// [`ServeConfig::default_priority`].
    pub priority: Option<Priority>,
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<WireRequest> {
    let j = Json::parse(line)?;
    let id = j.get("id")?.as_i64()? as u64;
    let prompt: Vec<i32> = j
        .get("prompt")?
        .as_arr()?
        .iter()
        .map(|t| Ok(t.as_i64()? as i32))
        .collect::<Result<_>>()?;
    let max_new = j.opt("max_new").map(|v| v.as_usize()).transpose()?.unwrap_or(32);
    let stream = j.opt("stream").map(|v| v.as_bool()).transpose()?;
    let deadline_ms = j
        .opt("deadline_ms")
        .map(|v| v.as_usize())
        .transpose()?
        .map(|ms| ms as u64);
    let priority = j
        .opt("priority")
        .map(|v| {
            let s = v.as_str()?;
            Priority::from_wire(s).ok_or_else(|| {
                anyhow!("unknown priority {s:?} (want \"interactive\" or \
                         \"batch\")")
            })
        })
        .transpose()?;
    Ok(WireRequest {
        req: Request::new(id, prompt, max_new),
        stream,
        deadline_ms,
        priority,
    })
}

/// Encode one completion line.
pub fn encode_completion(c: &Completion) -> String {
    Json::obj(vec![
        ("id", Json::Num(c.id as f64)),
        ("generated",
         Json::Arr(c.generated.iter().map(|&t| Json::Num(t as f64)).collect())),
        ("stop", Json::Str(c.stop.as_str().to_string())),
        ("ttft_ms", Json::Num(c.ttft.as_secs_f64() * 1e3)),
        ("e2e_ms", Json::Num(c.e2e.as_secs_f64() * 1e3)),
    ])
    .to_string()
}

/// Encode one streaming delta frame.
fn encode_delta(client_id: u64, tok: i32, index: usize) -> String {
    Json::obj(vec![
        ("id", Json::Num(client_id as f64)),
        ("delta", Json::Arr(vec![Json::Num(tok as f64)])),
        ("index", Json::Num(index as f64)),
    ])
    .to_string()
}

fn error_line(id: Option<u64>, msg: &str) -> String {
    let mut fields = Vec::new();
    if let Some(id) = id {
        fields.push(("id", Json::Num(id as f64)));
    }
    fields.push(("error", Json::Str(msg.to_string())));
    Json::obj(fields).to_string()
}

/// Encode a backpressure error reply: an error line that additionally
/// tells the client when to retry.
fn backpressure_line(id: u64, msg: &str, retry_after_ms: u64) -> String {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("error", Json::Str(msg.to_string())),
        ("retry_after_ms", Json::Num(retry_after_ms as f64)),
    ])
    .to_string()
}

/// Encode the non-terminal preemption notice for a streaming request.
fn encode_preempted(client_id: u64) -> String {
    Json::obj(vec![
        ("id", Json::Num(client_id as f64)),
        ("event", Json::Str("preempted".to_string())),
    ])
    .to_string()
}

/// One connection's state machine: accumulated partial line, pending
/// output, liveness bookkeeping.
struct Conn {
    stream: TcpStream,
    /// Bytes read but not yet terminated by a newline.
    rd: Vec<u8>,
    /// Encoded replies not yet accepted by the socket.
    wr: Vec<u8>,
    /// Last *useful* activity: accept, a completed request line, or a
    /// delivered reply. Raw bytes deliberately do not refresh it, so a
    /// byte-dripping slow-loris still ages out.
    last_activity: Instant,
    /// Requests submitted on this connection whose completions are owed.
    inflight: usize,
    /// Write interest currently registered with the reactor.
    want_write: bool,
    /// Flush `wr`, then close (goodbye messages).
    closing: bool,
    /// Peer half-closed its write side (we read EOF) — treated as
    /// departure: in-flight work is cancelled at its shard, and the
    /// (partial) replies still flush; the conn closes once nothing is
    /// owed.
    read_closed: bool,
}

// Vendored socket syscalls for `SO_REUSEPORT` listeners (x86-64/aarch64
// Linux ABI, same approach as the epoll shims in `super::reactor` — the
// offline vendor set has no libc crate).
const AF_INET: i32 = 2;
const SOCK_STREAM: i32 = 1;
const SOCK_CLOEXEC: i32 = 0o2000000;
const SOL_SOCKET: i32 = 1;
const SO_REUSEADDR: i32 = 2;
const SO_REUSEPORT: i32 = 15;

/// `struct sockaddr_in` (16 bytes); `sin_port` and `sin_addr` are in
/// network byte order.
#[repr(C)]
#[derive(Clone, Copy)]
struct SockAddrIn {
    sin_family: u16,
    sin_port: u16,
    sin_addr: u32,
    sin_zero: [u8; 8],
}

extern "C" {
    fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
    fn setsockopt(fd: i32, level: i32, optname: i32, optval: *const i32,
                  optlen: u32) -> i32;
    fn bind(fd: i32, addr: *const SockAddrIn, addrlen: u32) -> i32;
    fn listen(fd: i32, backlog: i32) -> i32;
    fn getsockname(fd: i32, addr: *mut SockAddrIn, addrlen: *mut u32) -> i32;
}

/// Bind `n` independent listeners to one address via `SO_REUSEPORT`
/// (the kernel load-balances accepts across them — the multi-reactor
/// fast path, one listener per reactor, no shared accept lock). Port 0
/// binds the first listener ephemeral and pins the rest to the port it
/// got. IPv4 only. Errors — including `ENOPROTOOPT` from a kernel
/// without `SO_REUSEPORT` — leave nothing bound; callers fall back to
/// single-listener accept handoff.
pub fn reuseport_listeners(addr: &str, n: usize) -> Result<Vec<TcpListener>> {
    let sa: std::net::SocketAddr =
        addr.parse().map_err(|e| anyhow!("parse {addr}: {e}"))?;
    let std::net::SocketAddr::V4(v4) = sa else {
        bail!("SO_REUSEPORT listeners support IPv4 addresses only");
    };
    let mut port = v4.port();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let fd = unsafe { socket(AF_INET, SOCK_STREAM | SOCK_CLOEXEC, 0) };
        if fd < 0 {
            bail!("socket: {}", std::io::Error::last_os_error());
        }
        // Wrap immediately: any error below drops (closes) the fd, and
        // earlier listeners in `out` close with it.
        let listener = unsafe { TcpListener::from_raw_fd(fd) };
        let one: i32 = 1;
        for opt in [SO_REUSEADDR, SO_REUSEPORT] {
            if unsafe { setsockopt(fd, SOL_SOCKET, opt, &one, 4) } < 0 {
                bail!("setsockopt(opt={opt}): {}",
                      std::io::Error::last_os_error());
            }
        }
        let sin = SockAddrIn {
            sin_family: AF_INET as u16,
            sin_port: port.to_be(),
            sin_addr: u32::from_ne_bytes(v4.ip().octets()),
            sin_zero: [0; 8],
        };
        let len = std::mem::size_of::<SockAddrIn>() as u32;
        if unsafe { bind(fd, &sin, len) } < 0 {
            bail!("bind {addr}: {}", std::io::Error::last_os_error());
        }
        if unsafe { listen(fd, 1024) } < 0 {
            bail!("listen: {}", std::io::Error::last_os_error());
        }
        if i == 0 && port == 0 {
            // Ephemeral bind: read the real port so siblings share it.
            let mut got = sin;
            let mut gl = len;
            if unsafe { getsockname(fd, &mut got, &mut gl) } < 0 {
                bail!("getsockname: {}", std::io::Error::last_os_error());
            }
            port = u16::from_be(got.sin_port);
        }
        out.push(listener);
    }
    Ok(out)
}

/// `SIGTERM`, vendored like the socket constants above (no libc crate
/// in the offline vendor set).
const SIGTERM: i32 = 15;

// Vendored signal syscalls for the graceful-drain hook. `write` is
// re-declared here (the reactor's declaration is module-private);
// duplicate extern declarations of one symbol are fine.
extern "C" {
    fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
}

/// Raw fd the SIGTERM handler writes to; `-1` until the drain hook is
/// armed. Split out of [`DRAIN_WAKE`] so the handler body is two
/// async-signal-safe operations: an atomic load and one `write(2)`.
static DRAIN_FD_RAW: AtomicI32 = AtomicI32::new(-1);

/// The process-global drain eventfd. Created at most once and never
/// closed — a signal can land at any instant, including between serve
/// loops, and the handler must always have a live fd to poke. `None`
/// records an `eventfd` failure so it is not retried forever.
static DRAIN_WAKE: OnceLock<Option<WakeFd>> = OnceLock::new();

/// The entire SIGTERM handler: bump the drain eventfd. Everything else
/// — broadcasting, listener teardown, goodbyes — happens on reactor 0's
/// thread when its epoll reports the [`DRAIN`] token.
extern "C" fn on_sigterm(_sig: i32) {
    let fd = DRAIN_FD_RAW.load(Ordering::Relaxed);
    if fd >= 0 {
        let one: u64 = 1;
        unsafe {
            write(fd, &one as *const u64 as *const u8,
                  std::mem::size_of::<u64>());
        }
    }
}

/// Arm the SIGTERM → drain hook (idempotent) and return the eventfd
/// reactor 0 registers under [`DRAIN`].
fn arm_sigterm_drain() -> Result<&'static WakeFd> {
    let wake = DRAIN_WAKE
        .get_or_init(|| WakeFd::new().ok())
        .as_ref()
        .ok_or_else(|| anyhow!("drain eventfd unavailable"))?;
    DRAIN_FD_RAW.store(wake.as_raw_fd(), Ordering::SeqCst);
    unsafe { signal(SIGTERM, on_sigterm) };
    Ok(wake)
}

/// How one reactor comes by its connections.
enum ListenerMode {
    /// This reactor owns a listener: the sole listener of a 1-reactor
    /// server, or its own `SO_REUSEPORT` socket in a fleet.
    Own(TcpListener),
    /// Fallback fleet, reactor 0: owns the only listener, keeps every
    /// N-th accepted connection, hands the rest to its peers.
    OwnAndDistribute(TcpListener, Vec<Sender<TcpStream>>),
    /// Fallback fleet, reactors 1..N: adopt connections reactor 0 hands
    /// over (each send is followed by a wake signal).
    Handoff(Receiver<TcpStream>),
}

/// Build the fallback modes for a fleet that must share one bound
/// listener: reactor 0 accepts and round-robins, the rest adopt.
fn handoff_modes(listener: TcpListener, n: usize) -> Vec<ListenerMode> {
    let mut txs = Vec::with_capacity(n - 1);
    let mut rest = Vec::with_capacity(n - 1);
    for _ in 1..n {
        let (tx, rx) = channel();
        txs.push(tx);
        rest.push(ListenerMode::Handoff(rx));
    }
    let mut out = Vec::with_capacity(n);
    out.push(ListenerMode::OwnAndDistribute(listener, txs));
    out.extend(rest);
    out
}

/// Fleet-wide serve state shared by all reactors.
struct ReactorShared {
    /// Completions delivered across the fleet ([`ServeConfig::limit`] is
    /// a fleet limit).
    served: AtomicUsize,
    /// Set when any reactor reaches the limit or fails; everyone exits.
    stop: AtomicBool,
    /// Set when SIGTERM asks for a graceful drain: stop accepting and
    /// reject new requests, but let in-flight work finish before
    /// exiting (contrast `stop`, which breaks the loop immediately).
    draining: AtomicBool,
    /// Every reactor's wake fd, indexed by reactor — for stop broadcast
    /// and accept-handoff nudges.
    wakes: Vec<Arc<WakeFd>>,
}

impl ReactorShared {
    /// Ask every reactor to wind down (they still drain their own lanes).
    fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for w in &self.wakes {
            w.signal();
        }
    }

    /// Flip the fleet into graceful drain (reactor 0, on SIGTERM).
    fn request_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
        for w in &self.wakes {
            w.signal();
        }
    }
}

/// Serve forever on `addr` across the group's shards.
pub fn serve<E: DecodeEngine + 'static>(group: EngineGroup<E>, addr: &str,
                                        cfg: ServeConfig) -> Result<()> {
    let n = resolve_reactors(cfg.reactors).min(group.n_lanes()).max(1);
    let modes = if n == 1 {
        let l = TcpListener::bind(addr).map_err(|e| anyhow!("bind {addr}: {e}"))?;
        vec![ListenerMode::Own(l)]
    } else {
        match reuseport_listeners(addr, n) {
            Ok(ls) => ls.into_iter().map(ListenerMode::Own).collect(),
            Err(e) => {
                eprintln!("[seerattn] SO_REUSEPORT listeners unavailable \
                           ({e}); falling back to accept handoff");
                let l = TcpListener::bind(addr)
                    .map_err(|e| anyhow!("bind {addr}: {e}"))?;
                handoff_modes(l, n)
            }
        }
    };
    eprintln!("[seerattn] serving on {addr} ({} shard{}, {} reactor{}, \
               max-conns {}, idle-timeout {:?}, queue-depth {})",
              group.n_shards(),
              if group.n_shards() == 1 { "" } else { "s" },
              n, if n == 1 { "" } else { "s" },
              cfg.max_conns, cfg.idle_timeout, group.queue_depth());
    serve_fleet(modes, group, cfg)
}

/// Serve on an already-bound listener. With `cfg.limit = Some(n)` the
/// loop exits after collecting `n` completions fleet-wide, drains
/// in-flight work, and prints the aggregated metrics on the way out.
/// With `cfg.reactors` > 1 the single pre-bound listener forces the
/// accept-handoff fallback (`SO_REUSEPORT` cannot be retrofitted onto a
/// bound socket) — which is exactly the path the fallback tests pin.
pub fn serve_on<E: DecodeEngine + 'static>(listener: TcpListener,
                                           group: EngineGroup<E>,
                                           cfg: ServeConfig) -> Result<()> {
    let n = resolve_reactors(cfg.reactors).min(group.n_lanes()).max(1);
    let modes = if n == 1 {
        vec![ListenerMode::Own(listener)]
    } else {
        handoff_modes(listener, n)
    };
    serve_fleet(modes, group, cfg)
}

/// Run one reactor per mode; the calling thread drives reactor 0 (the
/// lane that owns the shard fleet), collects every reactor's stats, and
/// performs the single group shutdown.
fn serve_fleet<E: DecodeEngine + 'static>(modes: Vec<ListenerMode>,
                                          group: EngineGroup<E>,
                                          cfg: ServeConfig) -> Result<()> {
    let n = modes.len();
    let wakes = (0..n)
        .map(|_| WakeFd::new().map(Arc::new))
        .collect::<Result<Vec<_>>>()?;
    let shared = Arc::new(ReactorShared {
        served: AtomicUsize::new(0),
        stop: AtomicBool::new(false),
        draining: AtomicBool::new(false),
        wakes,
    });
    let mut lanes = group.into_lanes();
    // Spare lanes beyond the reactor count (group built with more lanes
    // than reactors resolved) never receive submissions; drop them.
    lanes.truncate(n);
    let spawned: Vec<EngineGroup<E>> = lanes.drain(1..).collect();
    let lane0 = lanes.pop().expect("lane 0");
    let mut modes = modes.into_iter();
    let mode0 = modes.next().expect("mode 0");
    let mut handles = Vec::with_capacity(n - 1);
    for (k, (mode, lane)) in modes.zip(spawned).enumerate() {
        let r = k + 1;
        let shared = shared.clone();
        let wake = shared.wakes[r].clone();
        let h = std::thread::Builder::new()
            .name(format!("reactor-{r}"))
            .spawn(move || match FrontEnd::new(mode, lane, cfg, wake, shared) {
                Ok(fe) => {
                    let (_lane, stats, failure) = fe.run();
                    (stats, failure)
                }
                Err(e) => (ReactorStats::default(), Some(e)),
            })
            .map_err(|e| anyhow!("spawn reactor {r}: {e}"))?;
        handles.push(h);
    }
    let fe0 = match FrontEnd::new(mode0, lane0, cfg, shared.wakes[0].clone(),
                                  shared.clone()) {
        Ok(fe) => fe,
        Err(e) => {
            shared.request_stop();
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }
    };
    let (group0, stats0, mut failure) = fe0.run();
    let mut reactors = vec![ReactorStats::default(); n];
    reactors[0] = stats0;
    for (k, h) in handles.into_iter().enumerate() {
        match h.join() {
            Ok((stats, fail)) => {
                reactors[k + 1] = stats;
                if failure.is_none() {
                    failure = fail;
                }
            }
            Err(_) => {
                if failure.is_none() {
                    failure = Some(anyhow!("reactor {} panicked", k + 1));
                }
            }
        }
    }
    match failure {
        None => {
            let mut gm = group0.shutdown()?;
            gm.reactors = reactors;
            eprintln!("{}", gm.report());
            Ok(())
        }
        Some(e) => {
            // Best-effort teardown; the original failure is the story.
            let _ = group0.shutdown();
            Err(e)
        }
    }
}

/// Front-end bookkeeping for one accepted request.
struct InflightReq {
    /// Owning connection token.
    conn: u64,
    /// Client-visible id (internal ids are rewritten; see `next_req`).
    client_id: u64,
    /// Stream token deltas to the client as they are generated.
    stream: bool,
}

struct FrontEnd<E: DecodeEngine> {
    reactor: Reactor,
    mode: ListenerMode,
    /// This reactor's eventfd: registered at [`WAKER`], signalled by the
    /// shard fleet on every event for this lane, by reactor 0 on accept
    /// handoff, and by any reactor broadcasting stop.
    wake: Arc<WakeFd>,
    shared: Arc<ReactorShared>,
    /// This reactor's lane view of the group (ids ≡ lane mod lanes).
    group: EngineGroup<E>,
    cfg: ServeConfig,
    max_prompt: usize,
    conns: HashMap<u64, Conn>,
    /// Internal request id -> per-request front-end state.
    inflight: HashMap<u64, InflightReq>,
    next_token: u64,
    /// Next internal request id: starts at the lane index, strides by
    /// the lane count, so id ownership routes completions back here.
    next_req: u64,
    /// Round-robin cursor for accept handoff (reactor 0, fallback mode).
    next_handoff: usize,
    /// Earliest instant any idle/stuck eviction can fire; the O(conns)
    /// scan — and the epoll timeout — are driven by it.
    next_idle_check: Instant,
    /// This reactor has performed its drain transition (listener gone,
    /// idle conns goodbye'd); set once [`ReactorShared::draining`] is
    /// observed.
    draining: bool,
    stats: ReactorStats,
    failure: Option<anyhow::Error>,
}

impl<E: DecodeEngine> FrontEnd<E> {
    fn new(mode: ListenerMode, group: EngineGroup<E>, cfg: ServeConfig,
           wake: Arc<WakeFd>, shared: Arc<ReactorShared>)
           -> Result<FrontEnd<E>> {
        let reactor = Reactor::new()?;
        match &mode {
            ListenerMode::Own(l) | ListenerMode::OwnAndDistribute(l, _) => {
                l.set_nonblocking(true)?;
                reactor.register(l.as_raw_fd(), LISTENER, Interest::READ)?;
            }
            ListenerMode::Handoff(_) => {}
        }
        reactor.register(wake.as_raw_fd(), WAKER, Interest::READ)?;
        if cfg.drain_on_signal && group.lane() == 0 {
            // Reactor 0 watches the process-global drain eventfd and
            // broadcasts the transition to its peers.
            let drain = arm_sigterm_drain()?;
            reactor.register(drain.as_raw_fd(), DRAIN, Interest::READ)?;
        }
        group.register_wake(wake.clone());
        let max_prompt = group.max_prompt_len();
        let next_req = group.lane() as u64;
        Ok(FrontEnd {
            reactor,
            mode,
            wake,
            shared,
            group,
            cfg,
            max_prompt,
            conns: HashMap::new(),
            inflight: HashMap::new(),
            next_token: FIRST_CONN,
            next_req,
            next_handoff: 0,
            next_idle_check: Instant::now() + cfg.idle_timeout,
            draining: false,
            stats: ReactorStats::default(),
            failure: None,
        })
    }

    fn run(mut self) -> (EngineGroup<E>, ReactorStats, Option<anyhow::Error>) {
        let mut events: Vec<Event> = Vec::new();
        loop {
            if self.shared.stop.load(Ordering::SeqCst) {
                break;
            }
            if let Some(n) = self.cfg.limit {
                // Checked at loop entry so limit = Some(0) terminates
                // without waiting for a completion.
                if self.shared.served.load(Ordering::SeqCst) >= n {
                    self.shared.request_stop();
                    break;
                }
            }
            if self.failure.is_some() {
                break;
            }
            // The wake eventfd replaces the old completion-poll tick:
            // shard events, accept handoffs, and stop requests all
            // signal the fd, so the only *timed* work left is idle
            // eviction — park until its earliest deadline. An idle
            // server therefore blocks for the whole idle window in one
            // syscall, yet sees a completion the instant it is sent.
            let timeout = self
                .next_idle_check
                .saturating_duration_since(Instant::now())
                .clamp(Duration::from_millis(1), Duration::from_secs(600));
            if let Err(e) = self.reactor.wait(timeout, &mut events) {
                // Route through the failure path so the shard fleet is
                // still torn down and connections closed.
                self.failure = Some(e);
                break;
            }
            for ev in &events {
                match ev.token {
                    LISTENER => self.accept_ready(),
                    WAKER => {
                        self.wake.drain();
                        self.stats.wakes += 1;
                    }
                    DRAIN => {
                        // SIGTERM landed: clear the level-triggered
                        // eventfd and tell the whole fleet to drain.
                        if let Some(Some(w)) = DRAIN_WAKE.get() {
                            w.drain();
                        }
                        self.shared.request_drain();
                    }
                    token => {
                        if ev.readable {
                            self.conn_readable(token);
                        }
                        if ev.writable {
                            self.conn_writable(token);
                        }
                    }
                }
                if self.failure.is_some() {
                    break;
                }
            }
            self.adopt_handoffs();
            self.pump_events();
            self.evict_idle();
            if self.shared.draining.load(Ordering::SeqCst) {
                self.enter_drain();
                // Checked *after* pump_events so the completion that
                // empties the lane also ends the loop — otherwise the
                // reactor would park a full idle window on a dead lane.
                if self.group.inflight() == 0 {
                    break;
                }
            }
        }
        self.finish()
    }

    /// One-shot local transition into graceful drain: stop accepting
    /// (listener deregistered), goodbye connections with nothing in
    /// flight. Busy connections keep their replies coming and are
    /// goodbye'd by [`FrontEnd::deliver`] when their last one lands;
    /// [`FrontEnd::finish`] flushes whatever is still buffered.
    fn enter_drain(&mut self) {
        if self.draining {
            return;
        }
        self.draining = true;
        match &self.mode {
            ListenerMode::Own(l) | ListenerMode::OwnAndDistribute(l, _) => {
                let _ = self.reactor.deregister(l.as_raw_fd());
            }
            ListenerMode::Handoff(_) => {}
        }
        let idle: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| c.inflight == 0 && !c.closing)
            .map(|(&t, _)| t)
            .collect();
        for t in idle {
            self.queue_reply(
                t, &error_line(None, "server draining (SIGTERM), closing"));
            self.close_after_flush(t);
        }
    }

    /// Accept everything pending on this reactor's listener (if it has
    /// one) and place each connection — locally, or with a peer reactor
    /// in handoff mode.
    fn accept_ready(&mut self) {
        loop {
            let accepted = match &self.mode {
                ListenerMode::Own(l) => l.accept(),
                ListenerMode::OwnAndDistribute(l, _) => l.accept(),
                ListenerMode::Handoff(_) => return,
            };
            match accepted {
                Ok((stream, _)) => self.place(stream),
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => break,
            }
        }
    }

    /// Route a freshly accepted connection: round-robin across the fleet
    /// in handoff mode (reactor 0 keeps every N-th), local otherwise.
    fn place(&mut self, stream: TcpStream) {
        let n_peers = match &self.mode {
            ListenerMode::OwnAndDistribute(_, peers) => peers.len(),
            _ => 0,
        };
        if n_peers > 0 {
            let target = self.next_handoff % (n_peers + 1);
            self.next_handoff += 1;
            if target > 0 {
                let sent = match &self.mode {
                    ListenerMode::OwnAndDistribute(_, peers) => {
                        peers[target - 1].send(stream)
                    }
                    _ => unreachable!("n_peers > 0 only in distribute mode"),
                };
                match sent {
                    // The peer parks on its wake fd; nudge it to adopt.
                    Ok(()) => self.shared.wakes[target].signal(),
                    // Peer already exited (failure path): serve locally
                    // rather than dropping an accepted client.
                    Err(back) => self.adopt(back.0),
                }
                return;
            }
        }
        self.adopt(stream);
    }

    /// Adopt connections peers handed over (handoff fleet mode only).
    fn adopt_handoffs(&mut self) {
        loop {
            let next = match &self.mode {
                ListenerMode::Handoff(rx) => rx.try_recv().ok(),
                _ => None,
            };
            match next {
                Some(stream) => self.adopt(stream),
                None => break,
            }
        }
    }

    /// Take ownership of a connected stream: non-blocking mode, cap
    /// check (over-cap clients get a structured reply and an immediate
    /// close), reactor registration, bookkeeping.
    fn adopt(&mut self, stream: TcpStream) {
        if self.draining {
            // Raced into the accept queue (or a peer's handoff channel)
            // after the drain began: structured refusal and an
            // immediate close — never a silent drop.
            self.stats.conns_rejected += 1;
            let line = error_line(
                None, "server draining (SIGTERM), not accepting connections");
            let mut s = stream;
            let _ = s.write_all(line.as_bytes());
            let _ = s.write_all(b"\n");
            let _ = s.shutdown(std::net::Shutdown::Both);
            return;
        }
        if stream.set_nonblocking(true).is_err() {
            // A socket that cannot be made non-blocking is unusable, but
            // it must not vanish from the accounting (this was once a
            // silent drop): `conns_failed` keeps capacity math honest.
            self.stats.conns_failed += 1;
            return;
        }
        if self.conns.len() >= self.cfg.max_conns {
            self.stats.conns_rejected += 1;
            let line = error_line(
                None,
                &format!("server at connection capacity \
                          (max-conns {})", self.cfg.max_conns),
            );
            // Best effort: a fresh socket's send buffer is empty, so
            // this short line lands unless the peer is already gone.
            let mut s = stream;
            let _ = s.write_all(line.as_bytes());
            let _ = s.write_all(b"\n");
            let _ = s.shutdown(std::net::Shutdown::Both);
            return;
        }
        let token = self.next_token;
        self.next_token += 1;
        if self
            .reactor
            .register(stream.as_raw_fd(), token, Interest::READ)
            .is_err()
        {
            self.stats.conns_failed += 1;
            return;
        }
        self.stats.conns_accepted += 1;
        let now = Instant::now();
        self.conns.insert(token, Conn {
            stream,
            rd: Vec::new(),
            wr: Vec::new(),
            last_activity: now,
            inflight: 0,
            want_write: false,
            closing: false,
            read_closed: false,
        });
        self.note_idle_deadline(now + self.cfg.idle_timeout);
    }

    /// Record a new (earlier) eviction deadline; [`FrontEnd::evict_idle`]
    /// scans no later than the earliest recorded one. Refreshes that
    /// merely *extend* a connection's deadline need no call — a scan
    /// firing early just reschedules.
    fn note_idle_deadline(&mut self, at: Instant) {
        if at < self.next_idle_check {
            self.next_idle_check = at;
        }
    }

    fn conn_readable(&mut self, token: u64) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        if conn.read_closed {
            // Interest no longer includes IN/RDHUP, so a "readable"
            // event here can only be EPOLLHUP/EPOLLERR (always reported
            // by the kernel regardless of mask): the peer is fully gone,
            // replies are undeliverable, and leaving the fd registered
            // would level-trigger this event every wait — close now.
            self.close_conn(token);
            return;
        }
        let mut eof = false;
        let mut dead = false;
        let mut buf = [0u8; 4096];
        loop {
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    eof = true;
                    break;
                }
                Ok(n) => {
                    conn.rd.extend_from_slice(&buf[..n]);
                    // Cap intake per event: bounds `rd` against a
                    // newline-free flood, and yields to other
                    // connections (level-triggered epoll re-fires for
                    // whatever the kernel still holds).
                    if conn.rd.len() > MAX_LINE_BYTES {
                        break;
                    }
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    // Hard socket error (e.g. RST): unlike a clean EOF
                    // there is nothing left to deliver to this peer.
                    dead = true;
                    break;
                }
            }
        }
        // Split out complete lines, then release the borrow before
        // dispatching (dispatch needs &mut self for the router).
        let mut lines: Vec<String> = Vec::new();
        while let Some(pos) = conn.rd.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = conn.rd.drain(..=pos).collect();
            lines.push(String::from_utf8_lossy(&line).into_owned());
        }
        if eof && !conn.rd.is_empty() {
            // Clean EOF terminates a final unterminated line (the
            // BufRead::lines convention). Note that EOF also signals
            // departure: a request arriving *with* the EOF is submitted
            // and then immediately cancelled below — a client that wants
            // its reply must keep its write half open until it reads it.
            let tail: Vec<u8> = conn.rd.drain(..).collect();
            lines.push(String::from_utf8_lossy(&tail).into_owned());
        }
        let overlong = conn.rd.len() > MAX_LINE_BYTES;
        for line in &lines {
            self.handle_line(token, line);
        }
        if dead {
            self.close_conn(token);
        } else if overlong {
            self.queue_reply(token, &error_line(None, "request line too long"));
            self.close_after_flush(token);
        } else if eof {
            self.read_side_closed(token);
        }
    }

    /// The peer closed its write side (or errored): the client is
    /// treated as departed. In-flight decodes for this connection are
    /// **cancelled** at their owning shards (freeing slots and KV pages
    /// at the next step boundary) instead of running orphaned to
    /// completion; the resulting partial `"stop": "cancelled"` replies —
    /// and anything already buffered — still flush on the write half
    /// before the connection closes. Readability interest is dropped so
    /// a level-triggered EOF cannot spin the loop.
    fn read_side_closed(&mut self, token: u64) {
        self.cancel_owned(token);
        let Some(conn) = self.conns.get_mut(&token) else { return };
        conn.read_closed = true;
        if conn.inflight == 0 && conn.wr.is_empty() {
            self.close_conn(token);
            return;
        }
        let wants = !conn.wr.is_empty();
        conn.want_write = wants;
        let fd = conn.stream.as_raw_fd();
        let interest = Interest { readable: false, writable: wants };
        if self.reactor.modify(fd, token, interest).is_err() {
            self.close_conn(token);
        }
    }

    /// Parse and route one request line, queueing any reply.
    fn handle_line(&mut self, token: u64, line: &str) {
        if line.trim().is_empty() {
            return;
        }
        // A completed *non-empty* line is useful activity; raw bytes —
        // and bare newlines — are not (slow-loris defense).
        if let Some(conn) = self.conns.get_mut(&token) {
            conn.last_activity = Instant::now();
        }
        let wire = match parse_request(line) {
            Ok(r) => r,
            Err(e) => {
                // Through Json so the message is escaped (parse errors
                // quote the missing key).
                self.queue_reply(token, &error_line(None, &format!("{e}")));
                return;
            }
        };
        let req = wire.req;
        if self.draining {
            // The drain contract: everything routed before SIGTERM
            // completes; nothing new is admitted after it.
            self.queue_reply(
                token,
                &error_line(Some(req.id),
                            "server draining (SIGTERM), request not \
                             accepted"),
            );
            return;
        }
        // Reject instead of submitting: an over-long prompt would panic
        // the target shard's engine (context overflow).
        if req.prompt.len() > self.max_prompt {
            let msg = format!("prompt too long ({} > {} tokens)",
                              req.prompt.len(), self.max_prompt);
            self.queue_reply(token, &error_line(Some(req.id), &msg));
            return;
        }
        let stream = wire.stream.unwrap_or(self.cfg.stream_by_default);
        let deadline = wire
            .deadline_ms
            .map(Duration::from_millis)
            .or(self.cfg.deadline)
            .map(|d| Instant::now() + d);
        let priority = wire.priority.unwrap_or(self.cfg.default_priority);
        let client_id = req.id;
        let internal = self.next_req;
        let routed = self.group.submit(Request {
            id: internal,
            prompt: req.prompt,
            max_new: req.max_new,
            deadline,
            stream,
            priority,
        });
        match routed {
            Ok(SubmitOutcome::Routed(_)) => {
                // Stride by the lane count so this id stays this lane's.
                self.next_req += self.group.n_lanes() as u64;
                self.inflight.insert(internal, InflightReq {
                    conn: token,
                    client_id,
                    stream,
                });
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.inflight += 1;
                }
            }
            Ok(SubmitOutcome::Rejected) => {
                let msg = format!("overloaded: every shard at capacity \
                                   (queue-depth {}), retry later",
                                  self.group.queue_depth());
                self.queue_reply(token,
                                 &backpressure_line(client_id, &msg, 2));
            }
            Ok(SubmitOutcome::Deferred { retry_after_ms }) => {
                let msg = "deferred: no KV page headroom for this request \
                           right now, retry later";
                self.queue_reply(
                    token,
                    &backpressure_line(client_id, msg, retry_after_ms),
                );
            }
            Err(e) => self.failure = Some(e),
        }
    }

    /// Collect every lifecycle event the fleet has ready and fan the
    /// frames out to their owning connections: token deltas for
    /// streaming requests, the terminal reply line for everyone.
    fn pump_events(&mut self) {
        loop {
            match self.group.poll_event(Duration::ZERO) {
                Ok(Some(ev)) => self.handle_group_event(ev),
                Ok(None) => break,
                Err(e) => {
                    self.failure = Some(e);
                    break;
                }
            }
            if self.failure.is_some() {
                break;
            }
        }
    }

    fn handle_group_event(&mut self, ev: GroupEvent) {
        match ev {
            GroupEvent::Token { id, tok, index } => {
                // Non-streaming requests (and requests whose connection
                // died) drop their deltas here; the terminal reply is
                // unaffected.
                let Some(entry) = self.inflight.get(&id) else { return };
                if entry.stream {
                    let (conn, client_id) = (entry.conn, entry.client_id);
                    self.queue_reply(conn, &encode_delta(client_id, tok, index));
                }
            }
            GroupEvent::Preempted { id } => {
                // Non-terminal: tell a streaming client its delta stream
                // paused (it resumes at the next index); non-streaming
                // requests see nothing.
                let Some(entry) = self.inflight.get(&id) else { return };
                if entry.stream {
                    let (conn, client_id) = (entry.conn, entry.client_id);
                    self.queue_reply(conn, &encode_preempted(client_id));
                }
            }
            GroupEvent::Done(c) => {
                let served =
                    self.shared.served.fetch_add(1, Ordering::SeqCst) + 1;
                if self.cfg.limit.map_or(false, |n| served >= n) {
                    // Fleet limit reached: wake every reactor so no one
                    // keeps parking on an idle eventfd.
                    self.shared.request_stop();
                }
                self.deliver(c);
            }
        }
    }

    fn deliver(&mut self, mut c: Completion) {
        let Some(entry) = self.inflight.remove(&c.id) else {
            return;
        };
        let token = entry.conn;
        c.id = entry.client_id;
        let line = encode_completion(&c);
        let mut idle_from = None;
        if let Some(conn) = self.conns.get_mut(&token) {
            conn.inflight = conn.inflight.saturating_sub(1);
            conn.last_activity = Instant::now();
            if conn.inflight == 0 {
                // Back to idle-eligible: its eviction clock starts now.
                idle_from = Some(conn.last_activity);
            }
        }
        if let Some(at) = idle_from {
            self.note_idle_deadline(at + self.cfg.idle_timeout);
        }
        // The owning connection may be gone (client hung up mid-decode;
        // its work was cancelled at close): the completion is dropped.
        self.queue_reply(token, &line);
        if self.draining
            && self
                .conns
                .get(&token)
                .map_or(false, |c| c.inflight == 0 && !c.closing)
        {
            // Draining and this was the connection's last owed reply:
            // goodbye behind it, close once both frames flush.
            self.queue_reply(
                token, &error_line(None, "server draining (SIGTERM), closing"));
            self.close_after_flush(token);
        }
    }

    /// Evict connections with no in-flight work and no traffic inside
    /// the idle window. In-flight work keeps a connection alive no
    /// matter how long decode takes. The O(conns) scan runs only when
    /// the earliest tracked deadline (`next_idle_check`) is due — which
    /// also bounds the reactor's epoll timeout, so an idle reactor
    /// parks until exactly then instead of rescanning every tick.
    fn evict_idle(&mut self) {
        let now = Instant::now();
        if now < self.next_idle_check {
            return;
        }
        let cutoff = self.cfg.idle_timeout;
        let stale: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| {
                c.inflight == 0 && !c.closing && c.last_activity.elapsed() > cutoff
            })
            .map(|(&t, _)| t)
            .collect();
        for token in stale {
            self.stats.conns_evicted += 1;
            let line = error_line(
                None,
                &format!("idle timeout ({} ms), closing",
                         cutoff.as_millis()),
            );
            self.queue_reply(token, &line);
            self.close_after_flush(token);
        }
        // A closing connection whose peer stopped reading can never
        // drain its goodbye; don't let it linger past a second window.
        let stuck: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| c.closing && c.last_activity.elapsed() > cutoff * 2)
            .map(|(&t, _)| t)
            .collect();
        for token in stuck {
            self.close_conn(token);
        }
        // Reschedule: the earliest deadline among the survivors, one
        // idle window out when nothing is tracked. Connections with work
        // in flight re-enter via `deliver`'s note when they go idle.
        let mut next = now + cutoff;
        for c in self.conns.values() {
            let deadline = if c.closing {
                c.last_activity + cutoff * 2
            } else if c.inflight == 0 {
                c.last_activity + cutoff
            } else {
                continue;
            };
            if deadline < next {
                next = deadline;
            }
        }
        self.next_idle_check = next;
    }

    /// Queue `line` on the connection and push as much as the socket
    /// accepts right now. A client whose pending output exceeds
    /// [`MAX_WR_BYTES`] is a slow consumer and is dropped.
    fn queue_reply(&mut self, token: u64, line: &str) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        if conn.wr.len() + line.len() + 1 > MAX_WR_BYTES {
            self.stats.conns_evicted += 1;
            self.close_conn(token);
            return;
        }
        conn.wr.extend_from_slice(line.as_bytes());
        conn.wr.push(b'\n');
        self.flush_conn(token);
    }

    fn conn_writable(&mut self, token: u64) {
        self.flush_conn(token);
    }

    /// Write pending bytes; manage EPOLLOUT interest; close on error or
    /// when a `closing` connection fully drains.
    fn flush_conn(&mut self, token: u64) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        let mut written = 0usize;
        let mut dead = false;
        while written < conn.wr.len() {
            match conn.stream.write(&conn.wr[written..]) {
                Ok(0) => {
                    dead = true;
                    break;
                }
                Ok(n) => written += n,
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    dead = true;
                    break;
                }
            }
        }
        if written > 0 {
            conn.wr.drain(..written);
        }
        if dead {
            self.close_conn(token);
            return;
        }
        let wants = !conn.wr.is_empty();
        if wants != conn.want_write {
            conn.want_write = wants;
            let interest = Interest { readable: !conn.read_closed, writable: wants };
            let fd = conn.stream.as_raw_fd();
            if self.reactor.modify(fd, token, interest).is_err() {
                self.close_conn(token);
                return;
            }
        }
        if conn.wr.is_empty()
            && (conn.closing || (conn.read_closed && conn.inflight == 0))
        {
            self.close_conn(token);
        }
    }

    /// Mark the connection for close once its output drains (goodbye
    /// lines); closes immediately when nothing is pending.
    fn close_after_flush(&mut self, token: u64) {
        let mut stuck_at = None;
        if let Some(conn) = self.conns.get_mut(&token) {
            conn.closing = true;
            if conn.wr.is_empty() {
                self.close_conn(token);
            } else {
                // Its stuck-drain deadline is now tracked by the scan.
                stuck_at = Some(conn.last_activity);
            }
        }
        if let Some(at) = stuck_at {
            self.note_idle_deadline(at + self.cfg.idle_timeout * 2);
        }
    }

    /// Cancel every in-flight request owned by `token` at its shard —
    /// the decode is abandoned work once the client is gone, so its slot
    /// and KV pages are reclaimed at the next engine step instead of
    /// burning to completion. The `Finished(Cancelled)` completions
    /// still flow back and settle the inflight bookkeeping (and, if the
    /// write half survives, a partial reply).
    fn cancel_owned(&mut self, token: u64) {
        let ids: Vec<u64> = self
            .inflight
            .iter()
            .filter(|(_, e)| e.conn == token)
            .map(|(&id, _)| id)
            .collect();
        for id in ids {
            self.group.cancel(id);
        }
    }

    fn close_conn(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            let _ = self.reactor.deregister(conn.stream.as_raw_fd());
            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
            // Cancel the dead connection's decodes; their completions
            // are dropped on delivery (the inflight entries resolve to
            // a dead token).
            self.cancel_owned(token);
        }
    }

    /// Exit path: drain this lane's in-flight work (its replies still
    /// flush), close every owned connection, and hand the lane view back
    /// to [`serve_fleet`] — which joins the fleet and performs the one
    /// group shutdown.
    fn finish(mut self) -> (EngineGroup<E>, ReactorStats, Option<anyhow::Error>) {
        if self.failure.is_some() {
            // A failing reactor takes the fleet down with it.
            self.shared.request_stop();
        }
        if self.failure.is_none() {
            // The limit counts served replies: anything already routed
            // to a shard still gets its reply (and its delta frames)
            // before shutdown, so no accepted request is silently
            // dropped — and a shard failure during this drain is
            // surfaced exactly like one during the main loop.
            while self.group.inflight() > 0 && self.failure.is_none() {
                match self.group.poll_event(Duration::from_millis(5)) {
                    Ok(Some(ev)) => self.handle_group_event(ev),
                    Ok(None) => {}
                    Err(e) => self.failure = Some(e),
                }
            }
            if self.failure.is_some() {
                self.shared.request_stop();
            }
        }
        // Push queued replies out before closing; bounded patience so a
        // stalled peer cannot wedge shutdown.
        let deadline = Instant::now() + Duration::from_secs(2);
        while Instant::now() < deadline {
            let tokens: Vec<u64> = self
                .conns
                .iter()
                .filter(|(_, c)| !c.wr.is_empty())
                .map(|(&t, _)| t)
                .collect();
            if tokens.is_empty() {
                break;
            }
            for t in tokens {
                self.flush_conn(t);
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            self.close_conn(t);
        }
        (self.group, self.stats, self.failure)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{SeqStats, StopReason};

    #[test]
    fn parse_roundtrip() {
        let r = parse_request(r#"{"id": 7, "prompt": [1, 2, 3], "max_new": 16}"#).unwrap();
        assert_eq!(r.req.id, 7);
        assert_eq!(r.req.prompt, vec![1, 2, 3]);
        assert_eq!(r.req.max_new, 16);
        assert_eq!(r.stream, None);
        assert_eq!(r.deadline_ms, None);
        // default max_new
        let r = parse_request(r#"{"id": 1, "prompt": []}"#).unwrap();
        assert_eq!(r.req.max_new, 32);
        assert!(parse_request("{\"id\": 1}").is_err());
    }

    #[test]
    fn parse_stream_and_deadline_options() {
        let r = parse_request(
            r#"{"id": 2, "prompt": [4], "stream": true, "deadline_ms": 250}"#,
        )
        .unwrap();
        assert_eq!(r.stream, Some(true));
        assert_eq!(r.deadline_ms, Some(250));
        let r = parse_request(r#"{"id": 2, "prompt": [4], "stream": false}"#)
            .unwrap();
        assert_eq!(r.stream, Some(false));
        // Malformed option values are parse errors, not silent defaults.
        assert!(parse_request(r#"{"id": 2, "prompt": [4], "stream": 1}"#)
            .is_err());
        assert!(
            parse_request(r#"{"id": 2, "prompt": [4], "deadline_ms": -5}"#)
                .is_err()
        );
    }

    #[test]
    fn parse_priority_option() {
        let r = parse_request(
            r#"{"id": 2, "prompt": [4], "priority": "batch"}"#,
        )
        .unwrap();
        assert_eq!(r.priority, Some(Priority::Batch));
        let r = parse_request(
            r#"{"id": 2, "prompt": [4], "priority": "interactive"}"#,
        )
        .unwrap();
        assert_eq!(r.priority, Some(Priority::Interactive));
        let r = parse_request(r#"{"id": 2, "prompt": [4]}"#).unwrap();
        assert_eq!(r.priority, None);
        // Unknown classes are errors, not silent defaults.
        assert!(
            parse_request(r#"{"id": 2, "prompt": [4], "priority": "vip"}"#)
                .is_err()
        );
        assert!(parse_request(r#"{"id": 2, "prompt": [4], "priority": 3}"#)
            .is_err());
    }

    #[test]
    fn backpressure_lines_carry_retry_hint() {
        let j = Json::parse(&backpressure_line(7, "deferred: no headroom", 25))
            .unwrap();
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 7);
        assert_eq!(j.get("retry_after_ms").unwrap().as_i64().unwrap(), 25);
        assert!(j.get("error").unwrap().as_str().unwrap().starts_with("deferred"));
        assert!(j.get("stop").is_err(), "backpressure is not terminal");
    }

    #[test]
    fn preempted_frames_are_non_terminal_json() {
        let j = Json::parse(&encode_preempted(11)).unwrap();
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 11);
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "preempted");
        assert!(j.get("stop").is_err());
        assert!(j.get("error").is_err());
    }

    #[test]
    fn delta_frames_are_valid_json() {
        let line = encode_delta(9, 42, 3);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 9);
        assert_eq!(j.get("index").unwrap().as_i64().unwrap(), 3);
        let d = j.get("delta").unwrap().as_arr().unwrap();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].as_i64().unwrap(), 42);
        assert!(j.get("stop").is_err(), "deltas must not look terminal");
    }

    #[test]
    fn encode_completion_line() {
        let c = Completion {
            id: 3,
            prompt_len: 5,
            generated: vec![9, 2],
            stop: StopReason::Eos,
            ttft: Duration::from_millis(10),
            e2e: Duration::from_millis(20),
            stats: SeqStats::default(),
        };
        let line = encode_completion(&c);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 3);
        assert_eq!(j.get("stop").unwrap().as_str().unwrap(), "eos");
        assert_eq!(j.get("generated").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn reuseport_listeners_share_one_ephemeral_port() {
        match reuseport_listeners("127.0.0.1:0", 2) {
            Ok(ls) => {
                assert_eq!(ls.len(), 2);
                let p0 = ls[0].local_addr().unwrap().port();
                let p1 = ls[1].local_addr().unwrap().port();
                assert_eq!(p0, p1, "siblings must share the resolved port");
                assert_ne!(p0, 0, "ephemeral port must be resolved");
            }
            // Kernel without SO_REUSEPORT: serve() falls back to accept
            // handoff, which the e2e fallback test exercises directly.
            Err(_) => {}
        }
    }

    #[test]
    fn resolve_reactors_honours_explicit_and_clamps_auto() {
        assert_eq!(resolve_reactors(1), 1);
        assert_eq!(resolve_reactors(3), 3);
        let auto = resolve_reactors(0);
        assert!((1..=8).contains(&auto), "auto = {auto}");
    }

    #[test]
    fn sigterm_handler_pokes_the_drain_eventfd() {
        extern "C" {
            fn raise(sig: i32) -> i32;
        }
        let wake = arm_sigterm_drain().unwrap();
        // `raise` delivers to the calling thread before returning, so
        // the handler's eventfd write has landed by the next line.
        unsafe { raise(SIGTERM) };
        let r = Reactor::new().unwrap();
        r.register(wake.as_raw_fd(), DRAIN, Interest::READ).unwrap();
        let mut evs = Vec::new();
        r.wait(Duration::from_millis(500), &mut evs).unwrap();
        assert!(evs.iter().any(|e| e.token == DRAIN && e.readable),
                "drain eventfd must be readable after SIGTERM");
        // Leave the process-global fd clean for any other user.
        wake.drain();
    }

    #[test]
    fn error_lines_carry_optional_ids() {
        let j = Json::parse(&error_line(None, "nope")).unwrap();
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "nope");
        assert!(j.get("id").is_err());
        let j = Json::parse(&error_line(Some(9), "msg \"quoted\"")).unwrap();
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 9);
        assert!(j.get("error").unwrap().as_str().unwrap().contains("quoted"));
    }
}
