//! Event-driven TCP JSON-lines serving front-end over a sharded
//! [`EngineGroup`].
//!
//! Protocol (one JSON object per line):
//!   request:  {"id": 1, "prompt": [tok, ...], "max_new": 32}
//!             optional: "stream": true|false (overrides the server
//!             default), "deadline_ms": N (per-request deadline from
//!             arrival; overrides --deadline-ms), "priority":
//!             "interactive"|"batch" (scheduling class; overrides
//!             --default-priority)
//!   response: {"id": 1, "generated": [tok, ...], "stop": "eos",
//!              "ttft_ms": 12.3, "e2e_ms": 45.6}
//!   deltas:   streaming requests additionally get one
//!             {"delta": [tok], "id": 1, "index": K} frame per generated
//!             token *before* the terminal response line; the
//!             concatenated deltas equal the final "generated" array
//!             byte-for-byte (pinned by the streaming-parity test).
//!             A streaming request preempted mid-decode gets one
//!             {"id": 1, "event": "preempted"} frame; its delta stream
//!             resumes at the next index after re-admission (no token is
//!             repeated or lost).
//!   errors:   {"error": "..."} (parse) / {"id": N, "error": "..."}
//!             (per-request: prompt too long, overloaded). Backpressure
//!             errors additionally carry "retry_after_ms": N — when the
//!             router *deferred* the request for KV page headroom the
//!             hint is its configured retry window; a capacity
//!             rejection uses a short fixed hint.
//!
//! "stop" may also be "cancelled" (the client went away mid-decode),
//! "deadline" (the per-request deadline expired), or
//! "resource_exhausted" (preempted for memory and out of retry budget);
//! all carry whatever was generated up to that point.
//!
//! The front-end is a **single-threaded reactor** over raw epoll (see
//! [`super::reactor`]): one thread drives non-blocking accept, reads,
//! writes, and engine-completion fan-out over per-connection state
//! machines with partial-read/partial-write buffers. Compared to the
//! previous thread-per-connection design this caps front-end cost at one
//! thread regardless of connection count and makes hard limits
//! enforceable:
//!
//! - **connection cap** (`max_conns`): excess clients get a structured
//!   error reply and are closed immediately — no unbounded thread spawn.
//! - **idle timeout** (`idle_timeout`): a connection with no in-flight
//!   work and no *completed request line* inside the window is evicted
//!   with a structured goodbye. Raw bytes do not refresh the clock, so
//!   a slow-loris dripping a partial line cannot hold a slot.
//! - **admission backpressure**: when the router reports every shard at
//!   `batch + queue_depth` load, the request is answered with an
//!   `overloaded` error instead of queueing unboundedly.
//! - **cancel propagation**: a connection that goes away — read-side
//!   EOF, hard socket error, slow-consumer drop, or eviction — has its
//!   in-flight requests *cancelled* at the owning shard instead of
//!   orphaning the decode: the engine frees the slot and KV pages at its
//!   next step boundary. (Read-side EOF therefore means "client is
//!   done": the cancelled partial replies still flush on the write half,
//!   but EOF no longer lets a departed client's decode run to
//!   completion.)
//! - **streaming backpressure**: delta frames accumulate (coalesce) in
//!   the bounded per-connection write buffer and drain under EPOLLOUT; a
//!   reader that falls [`MAX_WR_BYTES`] behind is dropped — which, per
//!   the above, cancels its in-flight decodes. Never unbounded.
//!
//! Ids are rewritten internally so concurrent clients cannot collide.
//! (The offline vendor set has no tokio; epoll + std::net provides the
//! same architecture.)

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::reactor::{Event, Interest, Reactor};
use super::request::{Completion, Priority, Request};
use super::shard::{EngineGroup, GroupEvent, SubmitOutcome};
use super::DecodeEngine;
use crate::util::json::Json;

/// Reactor token reserved for the listener; connections get tokens
/// starting at 1.
const LISTENER: u64 = 0;

/// A request line longer than this (no newline seen yet) is answered
/// with an error and the connection closed — a reasonable bound for a
/// token-id array protocol, and a guard against memory exhaustion.
const MAX_LINE_BYTES: usize = 1 << 20;

/// Pending-reply bytes beyond this mean the client is not draining its
/// socket; the connection is dropped rather than buffering without
/// bound (the blocking write this design replaced applied the same
/// pressure by stalling the writer).
const MAX_WR_BYTES: usize = 8 << 20;

/// Front-end limits; `Default` gives production-ish values, tests
/// override.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Hard cap on concurrently open connections; excess accepts get a
    /// structured error reply and an immediate close.
    pub max_conns: usize,
    /// Connections with no in-flight work and no traffic for this long
    /// are evicted (structured goodbye, then close).
    pub idle_timeout: Duration,
    /// Stop after this many completions have been collected (tests bind
    /// port 0 and set a limit); `None` serves forever.
    pub limit: Option<usize>,
    /// Stream token deltas for every request unless it says
    /// `"stream": false` (CLI `--stream`). Off by default: requests
    /// opt in with `"stream": true`.
    pub stream_by_default: bool,
    /// Server-imposed default deadline applied to every request that
    /// does not carry its own `deadline_ms` (CLI `--deadline-ms`);
    /// `None` = unbounded.
    pub deadline: Option<Duration>,
    /// Scheduling class for requests that carry no `"priority"` field
    /// (CLI `--default-priority`).
    pub default_priority: Priority,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_conns: 256,
            idle_timeout: Duration::from_secs(30),
            limit: None,
            stream_by_default: false,
            deadline: None,
            default_priority: Priority::default(),
        }
    }
}

/// One parsed request line: the request itself plus the per-request
/// protocol options that belong to the front-end, not the engine.
pub struct WireRequest {
    pub req: Request,
    /// `"stream"` field: `Some` overrides
    /// [`ServeConfig::stream_by_default`].
    pub stream: Option<bool>,
    /// `"deadline_ms"` field: `Some` overrides [`ServeConfig::deadline`].
    pub deadline_ms: Option<u64>,
    /// `"priority"` field: `Some` overrides
    /// [`ServeConfig::default_priority`].
    pub priority: Option<Priority>,
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<WireRequest> {
    let j = Json::parse(line)?;
    let id = j.get("id")?.as_i64()? as u64;
    let prompt: Vec<i32> = j
        .get("prompt")?
        .as_arr()?
        .iter()
        .map(|t| Ok(t.as_i64()? as i32))
        .collect::<Result<_>>()?;
    let max_new = j.opt("max_new").map(|v| v.as_usize()).transpose()?.unwrap_or(32);
    let stream = j.opt("stream").map(|v| v.as_bool()).transpose()?;
    let deadline_ms = j
        .opt("deadline_ms")
        .map(|v| v.as_usize())
        .transpose()?
        .map(|ms| ms as u64);
    let priority = j
        .opt("priority")
        .map(|v| {
            let s = v.as_str()?;
            Priority::from_wire(s).ok_or_else(|| {
                anyhow!("unknown priority {s:?} (want \"interactive\" or \
                         \"batch\")")
            })
        })
        .transpose()?;
    Ok(WireRequest {
        req: Request::new(id, prompt, max_new),
        stream,
        deadline_ms,
        priority,
    })
}

/// Encode one completion line.
pub fn encode_completion(c: &Completion) -> String {
    Json::obj(vec![
        ("id", Json::Num(c.id as f64)),
        ("generated",
         Json::Arr(c.generated.iter().map(|&t| Json::Num(t as f64)).collect())),
        ("stop", Json::Str(c.stop.as_str().to_string())),
        ("ttft_ms", Json::Num(c.ttft.as_secs_f64() * 1e3)),
        ("e2e_ms", Json::Num(c.e2e.as_secs_f64() * 1e3)),
    ])
    .to_string()
}

/// Encode one streaming delta frame.
fn encode_delta(client_id: u64, tok: i32, index: usize) -> String {
    Json::obj(vec![
        ("id", Json::Num(client_id as f64)),
        ("delta", Json::Arr(vec![Json::Num(tok as f64)])),
        ("index", Json::Num(index as f64)),
    ])
    .to_string()
}

fn error_line(id: Option<u64>, msg: &str) -> String {
    let mut fields = Vec::new();
    if let Some(id) = id {
        fields.push(("id", Json::Num(id as f64)));
    }
    fields.push(("error", Json::Str(msg.to_string())));
    Json::obj(fields).to_string()
}

/// Encode a backpressure error reply: an error line that additionally
/// tells the client when to retry.
fn backpressure_line(id: u64, msg: &str, retry_after_ms: u64) -> String {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("error", Json::Str(msg.to_string())),
        ("retry_after_ms", Json::Num(retry_after_ms as f64)),
    ])
    .to_string()
}

/// Encode the non-terminal preemption notice for a streaming request.
fn encode_preempted(client_id: u64) -> String {
    Json::obj(vec![
        ("id", Json::Num(client_id as f64)),
        ("event", Json::Str("preempted".to_string())),
    ])
    .to_string()
}

/// One connection's state machine: accumulated partial line, pending
/// output, liveness bookkeeping.
struct Conn {
    stream: TcpStream,
    /// Bytes read but not yet terminated by a newline.
    rd: Vec<u8>,
    /// Encoded replies not yet accepted by the socket.
    wr: Vec<u8>,
    /// Last *useful* activity: accept, a completed request line, or a
    /// delivered reply. Raw bytes deliberately do not refresh it, so a
    /// byte-dripping slow-loris still ages out.
    last_activity: Instant,
    /// Requests submitted on this connection whose completions are owed.
    inflight: usize,
    /// Write interest currently registered with the reactor.
    want_write: bool,
    /// Flush `wr`, then close (goodbye messages).
    closing: bool,
    /// Peer half-closed its write side (we read EOF) — treated as
    /// departure: in-flight work is cancelled at its shard, and the
    /// (partial) replies still flush; the conn closes once nothing is
    /// owed.
    read_closed: bool,
}

/// Serve forever on `addr` across the group's shards.
pub fn serve<E: DecodeEngine>(group: EngineGroup<E>, addr: &str,
                              cfg: ServeConfig) -> Result<()> {
    let listener = TcpListener::bind(addr).map_err(|e| anyhow!("bind {addr}: {e}"))?;
    eprintln!("[seerattn] serving on {addr} ({} shard{}, max-conns {}, \
               idle-timeout {:?}, queue-depth {})",
              group.n_shards(),
              if group.n_shards() == 1 { "" } else { "s" },
              cfg.max_conns, cfg.idle_timeout, group.queue_depth());
    serve_on(listener, group, cfg)
}

/// Serve on an already-bound listener. With `cfg.limit = Some(n)` the
/// loop exits after collecting `n` completions, drains in-flight work,
/// and prints the aggregated fleet metrics on the way out.
pub fn serve_on<E: DecodeEngine>(listener: TcpListener, group: EngineGroup<E>,
                                 cfg: ServeConfig) -> Result<()> {
    FrontEnd::new(listener, group, cfg)?.run()
}

/// Front-end bookkeeping for one accepted request.
struct InflightReq {
    /// Owning connection token.
    conn: u64,
    /// Client-visible id (internal ids are rewritten; see `next_req`).
    client_id: u64,
    /// Stream token deltas to the client as they are generated.
    stream: bool,
}

struct FrontEnd<E: DecodeEngine> {
    reactor: Reactor,
    listener: TcpListener,
    group: EngineGroup<E>,
    cfg: ServeConfig,
    max_prompt: usize,
    conns: HashMap<u64, Conn>,
    /// Internal request id -> per-request front-end state.
    inflight: HashMap<u64, InflightReq>,
    next_token: u64,
    next_req: u64,
    served: usize,
    conns_rejected: u64,
    conns_evicted: u64,
    failure: Option<anyhow::Error>,
}

impl<E: DecodeEngine> FrontEnd<E> {
    fn new(listener: TcpListener, group: EngineGroup<E>,
           cfg: ServeConfig) -> Result<FrontEnd<E>> {
        listener.set_nonblocking(true)?;
        let reactor = Reactor::new()?;
        reactor.register(listener.as_raw_fd(), LISTENER, Interest::READ)?;
        let max_prompt = group.max_prompt_len();
        Ok(FrontEnd {
            reactor,
            listener,
            group,
            cfg,
            max_prompt,
            conns: HashMap::new(),
            inflight: HashMap::new(),
            next_token: 1,
            next_req: 0,
            served: 0,
            conns_rejected: 0,
            conns_evicted: 0,
            failure: None,
        })
    }

    fn run(mut self) -> Result<()> {
        let mut events: Vec<Event> = Vec::new();
        loop {
            if let Some(n) = self.cfg.limit {
                // Checked at loop entry so limit = Some(0) terminates
                // without waiting for a completion.
                if self.served >= n {
                    break;
                }
            }
            if self.failure.is_some() {
                break;
            }
            // Completions can only arrive while work is in flight; when
            // nothing is, wait longer per syscall (idle eviction still
            // ticks, just at coarser granularity).
            let timeout = if self.group.inflight() > 0 {
                Duration::from_millis(1)
            } else {
                Duration::from_millis(20)
            };
            if let Err(e) = self.reactor.wait(timeout, &mut events) {
                // Route through the failure path so the shard fleet is
                // still torn down and connections closed.
                self.failure = Some(e);
                break;
            }
            for ev in &events {
                if ev.token == LISTENER {
                    self.accept_ready();
                } else {
                    if ev.readable {
                        self.conn_readable(ev.token);
                    }
                    if ev.writable {
                        self.conn_writable(ev.token);
                    }
                }
                if self.failure.is_some() {
                    break;
                }
            }
            self.pump_events();
            self.evict_idle();
        }
        self.finish()
    }

    /// Accept everything pending; over-cap clients get a structured
    /// reply and an immediate close.
    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    if self.conns.len() >= self.cfg.max_conns {
                        self.conns_rejected += 1;
                        let line = error_line(
                            None,
                            &format!("server at connection capacity \
                                      (max-conns {})", self.cfg.max_conns),
                        );
                        // Best effort: a fresh socket's send buffer is
                        // empty, so this short line lands unless the
                        // peer is already gone.
                        let mut s = stream;
                        let _ = s.write_all(line.as_bytes());
                        let _ = s.write_all(b"\n");
                        let _ = s.shutdown(std::net::Shutdown::Both);
                        continue;
                    }
                    let token = self.next_token;
                    self.next_token += 1;
                    if self
                        .reactor
                        .register(stream.as_raw_fd(), token, Interest::READ)
                        .is_err()
                    {
                        continue;
                    }
                    self.conns.insert(token, Conn {
                        stream,
                        rd: Vec::new(),
                        wr: Vec::new(),
                        last_activity: Instant::now(),
                        inflight: 0,
                        want_write: false,
                        closing: false,
                        read_closed: false,
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => break,
            }
        }
    }

    fn conn_readable(&mut self, token: u64) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        if conn.read_closed {
            // Interest no longer includes IN/RDHUP, so a "readable"
            // event here can only be EPOLLHUP/EPOLLERR (always reported
            // by the kernel regardless of mask): the peer is fully gone,
            // replies are undeliverable, and leaving the fd registered
            // would level-trigger this event every wait — close now.
            self.close_conn(token);
            return;
        }
        let mut eof = false;
        let mut dead = false;
        let mut buf = [0u8; 4096];
        loop {
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    eof = true;
                    break;
                }
                Ok(n) => {
                    conn.rd.extend_from_slice(&buf[..n]);
                    // Cap intake per event: bounds `rd` against a
                    // newline-free flood, and yields to other
                    // connections (level-triggered epoll re-fires for
                    // whatever the kernel still holds).
                    if conn.rd.len() > MAX_LINE_BYTES {
                        break;
                    }
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    // Hard socket error (e.g. RST): unlike a clean EOF
                    // there is nothing left to deliver to this peer.
                    dead = true;
                    break;
                }
            }
        }
        // Split out complete lines, then release the borrow before
        // dispatching (dispatch needs &mut self for the router).
        let mut lines: Vec<String> = Vec::new();
        while let Some(pos) = conn.rd.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = conn.rd.drain(..=pos).collect();
            lines.push(String::from_utf8_lossy(&line).into_owned());
        }
        if eof && !conn.rd.is_empty() {
            // Clean EOF terminates a final unterminated line (the
            // BufRead::lines convention). Note that EOF also signals
            // departure: a request arriving *with* the EOF is submitted
            // and then immediately cancelled below — a client that wants
            // its reply must keep its write half open until it reads it.
            let tail: Vec<u8> = conn.rd.drain(..).collect();
            lines.push(String::from_utf8_lossy(&tail).into_owned());
        }
        let overlong = conn.rd.len() > MAX_LINE_BYTES;
        for line in &lines {
            self.handle_line(token, line);
        }
        if dead {
            self.close_conn(token);
        } else if overlong {
            self.queue_reply(token, &error_line(None, "request line too long"));
            self.close_after_flush(token);
        } else if eof {
            self.read_side_closed(token);
        }
    }

    /// The peer closed its write side (or errored): the client is
    /// treated as departed. In-flight decodes for this connection are
    /// **cancelled** at their owning shards (freeing slots and KV pages
    /// at the next step boundary) instead of running orphaned to
    /// completion; the resulting partial `"stop": "cancelled"` replies —
    /// and anything already buffered — still flush on the write half
    /// before the connection closes. Readability interest is dropped so
    /// a level-triggered EOF cannot spin the loop.
    fn read_side_closed(&mut self, token: u64) {
        self.cancel_owned(token);
        let Some(conn) = self.conns.get_mut(&token) else { return };
        conn.read_closed = true;
        if conn.inflight == 0 && conn.wr.is_empty() {
            self.close_conn(token);
            return;
        }
        let wants = !conn.wr.is_empty();
        conn.want_write = wants;
        let fd = conn.stream.as_raw_fd();
        let interest = Interest { readable: false, writable: wants };
        if self.reactor.modify(fd, token, interest).is_err() {
            self.close_conn(token);
        }
    }

    /// Parse and route one request line, queueing any reply.
    fn handle_line(&mut self, token: u64, line: &str) {
        if line.trim().is_empty() {
            return;
        }
        // A completed *non-empty* line is useful activity; raw bytes —
        // and bare newlines — are not (slow-loris defense).
        if let Some(conn) = self.conns.get_mut(&token) {
            conn.last_activity = Instant::now();
        }
        let wire = match parse_request(line) {
            Ok(r) => r,
            Err(e) => {
                // Through Json so the message is escaped (parse errors
                // quote the missing key).
                self.queue_reply(token, &error_line(None, &format!("{e}")));
                return;
            }
        };
        let req = wire.req;
        // Reject instead of submitting: an over-long prompt would panic
        // the target shard's engine (context overflow).
        if req.prompt.len() > self.max_prompt {
            let msg = format!("prompt too long ({} > {} tokens)",
                              req.prompt.len(), self.max_prompt);
            self.queue_reply(token, &error_line(Some(req.id), &msg));
            return;
        }
        let stream = wire.stream.unwrap_or(self.cfg.stream_by_default);
        let deadline = wire
            .deadline_ms
            .map(Duration::from_millis)
            .or(self.cfg.deadline)
            .map(|d| Instant::now() + d);
        let priority = wire.priority.unwrap_or(self.cfg.default_priority);
        let client_id = req.id;
        let internal = self.next_req;
        let routed = self.group.submit(Request {
            id: internal,
            prompt: req.prompt,
            max_new: req.max_new,
            deadline,
            stream,
            priority,
        });
        match routed {
            Ok(SubmitOutcome::Routed(_)) => {
                self.next_req += 1;
                self.inflight.insert(internal, InflightReq {
                    conn: token,
                    client_id,
                    stream,
                });
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.inflight += 1;
                }
            }
            Ok(SubmitOutcome::Rejected) => {
                let msg = format!("overloaded: every shard at capacity \
                                   (queue-depth {}), retry later",
                                  self.group.queue_depth());
                self.queue_reply(token,
                                 &backpressure_line(client_id, &msg, 2));
            }
            Ok(SubmitOutcome::Deferred { retry_after_ms }) => {
                let msg = "deferred: no KV page headroom for this request \
                           right now, retry later";
                self.queue_reply(
                    token,
                    &backpressure_line(client_id, msg, retry_after_ms),
                );
            }
            Err(e) => self.failure = Some(e),
        }
    }

    /// Collect every lifecycle event the fleet has ready and fan the
    /// frames out to their owning connections: token deltas for
    /// streaming requests, the terminal reply line for everyone.
    fn pump_events(&mut self) {
        loop {
            match self.group.poll_event(Duration::ZERO) {
                Ok(Some(ev)) => self.handle_group_event(ev),
                Ok(None) => break,
                Err(e) => {
                    self.failure = Some(e);
                    break;
                }
            }
            if self.failure.is_some() {
                break;
            }
        }
    }

    fn handle_group_event(&mut self, ev: GroupEvent) {
        match ev {
            GroupEvent::Token { id, tok, index } => {
                // Non-streaming requests (and requests whose connection
                // died) drop their deltas here; the terminal reply is
                // unaffected.
                let Some(entry) = self.inflight.get(&id) else { return };
                if entry.stream {
                    let (conn, client_id) = (entry.conn, entry.client_id);
                    self.queue_reply(conn, &encode_delta(client_id, tok, index));
                }
            }
            GroupEvent::Preempted { id } => {
                // Non-terminal: tell a streaming client its delta stream
                // paused (it resumes at the next index); non-streaming
                // requests see nothing.
                let Some(entry) = self.inflight.get(&id) else { return };
                if entry.stream {
                    let (conn, client_id) = (entry.conn, entry.client_id);
                    self.queue_reply(conn, &encode_preempted(client_id));
                }
            }
            GroupEvent::Done(c) => {
                self.served += 1;
                self.deliver(c);
            }
        }
    }

    fn deliver(&mut self, mut c: Completion) {
        let Some(entry) = self.inflight.remove(&c.id) else {
            return;
        };
        let token = entry.conn;
        c.id = entry.client_id;
        let line = encode_completion(&c);
        if let Some(conn) = self.conns.get_mut(&token) {
            conn.inflight = conn.inflight.saturating_sub(1);
            conn.last_activity = Instant::now();
        }
        // The owning connection may be gone (client hung up mid-decode;
        // its work was cancelled at close): the completion is dropped.
        self.queue_reply(token, &line);
    }

    /// Evict connections with no in-flight work and no traffic inside
    /// the idle window. In-flight work keeps a connection alive no
    /// matter how long decode takes.
    fn evict_idle(&mut self) {
        let cutoff = self.cfg.idle_timeout;
        let stale: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| {
                c.inflight == 0 && !c.closing && c.last_activity.elapsed() > cutoff
            })
            .map(|(&t, _)| t)
            .collect();
        for token in stale {
            self.conns_evicted += 1;
            let line = error_line(
                None,
                &format!("idle timeout ({} ms), closing",
                         cutoff.as_millis()),
            );
            self.queue_reply(token, &line);
            self.close_after_flush(token);
        }
        // A closing connection whose peer stopped reading can never
        // drain its goodbye; don't let it linger past a second window.
        let stuck: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| c.closing && c.last_activity.elapsed() > cutoff * 2)
            .map(|(&t, _)| t)
            .collect();
        for token in stuck {
            self.close_conn(token);
        }
    }

    /// Queue `line` on the connection and push as much as the socket
    /// accepts right now. A client whose pending output exceeds
    /// [`MAX_WR_BYTES`] is a slow consumer and is dropped.
    fn queue_reply(&mut self, token: u64, line: &str) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        if conn.wr.len() + line.len() + 1 > MAX_WR_BYTES {
            self.conns_evicted += 1;
            self.close_conn(token);
            return;
        }
        conn.wr.extend_from_slice(line.as_bytes());
        conn.wr.push(b'\n');
        self.flush_conn(token);
    }

    fn conn_writable(&mut self, token: u64) {
        self.flush_conn(token);
    }

    /// Write pending bytes; manage EPOLLOUT interest; close on error or
    /// when a `closing` connection fully drains.
    fn flush_conn(&mut self, token: u64) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        let mut written = 0usize;
        let mut dead = false;
        while written < conn.wr.len() {
            match conn.stream.write(&conn.wr[written..]) {
                Ok(0) => {
                    dead = true;
                    break;
                }
                Ok(n) => written += n,
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    dead = true;
                    break;
                }
            }
        }
        if written > 0 {
            conn.wr.drain(..written);
        }
        if dead {
            self.close_conn(token);
            return;
        }
        let wants = !conn.wr.is_empty();
        if wants != conn.want_write {
            conn.want_write = wants;
            let interest = Interest { readable: !conn.read_closed, writable: wants };
            let fd = conn.stream.as_raw_fd();
            if self.reactor.modify(fd, token, interest).is_err() {
                self.close_conn(token);
                return;
            }
        }
        if conn.wr.is_empty()
            && (conn.closing || (conn.read_closed && conn.inflight == 0))
        {
            self.close_conn(token);
        }
    }

    /// Mark the connection for close once its output drains (goodbye
    /// lines); closes immediately when nothing is pending.
    fn close_after_flush(&mut self, token: u64) {
        if let Some(conn) = self.conns.get_mut(&token) {
            conn.closing = true;
            if conn.wr.is_empty() {
                self.close_conn(token);
            }
        }
    }

    /// Cancel every in-flight request owned by `token` at its shard —
    /// the decode is abandoned work once the client is gone, so its slot
    /// and KV pages are reclaimed at the next engine step instead of
    /// burning to completion. The `Finished(Cancelled)` completions
    /// still flow back and settle the inflight bookkeeping (and, if the
    /// write half survives, a partial reply).
    fn cancel_owned(&mut self, token: u64) {
        let ids: Vec<u64> = self
            .inflight
            .iter()
            .filter(|(_, e)| e.conn == token)
            .map(|(&id, _)| id)
            .collect();
        for id in ids {
            self.group.cancel(id);
        }
    }

    fn close_conn(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            let _ = self.reactor.deregister(conn.stream.as_raw_fd());
            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
            // Cancel the dead connection's decodes; their completions
            // are dropped on delivery (the inflight entries resolve to
            // a dead token).
            self.cancel_owned(token);
        }
    }

    /// Exit path: drain in-flight work (its replies still flush), report
    /// fleet metrics, close every connection.
    fn finish(mut self) -> Result<()> {
        if self.failure.is_none() {
            // The limit counts served replies: anything already routed
            // to a shard still gets its reply (and its delta frames)
            // before shutdown, so no accepted request is silently
            // dropped — and a shard failure during this drain is
            // surfaced exactly like one during the main loop.
            while self.group.inflight() > 0 && self.failure.is_none() {
                match self.group.poll_event(Duration::from_millis(5)) {
                    Ok(Some(ev)) => self.handle_group_event(ev),
                    Ok(None) => {}
                    Err(e) => self.failure = Some(e),
                }
            }
        }
        // Push queued replies out before closing; bounded patience so a
        // stalled peer cannot wedge shutdown.
        let deadline = Instant::now() + Duration::from_secs(2);
        while Instant::now() < deadline {
            let tokens: Vec<u64> = self
                .conns
                .iter()
                .filter(|(_, c)| !c.wr.is_empty())
                .map(|(&t, _)| t)
                .collect();
            if tokens.is_empty() {
                break;
            }
            for t in tokens {
                self.flush_conn(t);
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            self.close_conn(t);
        }
        if self.conns_rejected + self.conns_evicted > 0 {
            eprintln!("[seerattn] front-end: {} connection(s) rejected at cap, \
                       {} evicted idle",
                      self.conns_rejected, self.conns_evicted);
        }
        match self.failure {
            None => self.group.shutdown().map(|gm| eprintln!("{}", gm.report())),
            Some(e) => {
                // Best-effort teardown; the original failure is the story.
                let _ = self.group.shutdown();
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{SeqStats, StopReason};

    #[test]
    fn parse_roundtrip() {
        let r = parse_request(r#"{"id": 7, "prompt": [1, 2, 3], "max_new": 16}"#).unwrap();
        assert_eq!(r.req.id, 7);
        assert_eq!(r.req.prompt, vec![1, 2, 3]);
        assert_eq!(r.req.max_new, 16);
        assert_eq!(r.stream, None);
        assert_eq!(r.deadline_ms, None);
        // default max_new
        let r = parse_request(r#"{"id": 1, "prompt": []}"#).unwrap();
        assert_eq!(r.req.max_new, 32);
        assert!(parse_request("{\"id\": 1}").is_err());
    }

    #[test]
    fn parse_stream_and_deadline_options() {
        let r = parse_request(
            r#"{"id": 2, "prompt": [4], "stream": true, "deadline_ms": 250}"#,
        )
        .unwrap();
        assert_eq!(r.stream, Some(true));
        assert_eq!(r.deadline_ms, Some(250));
        let r = parse_request(r#"{"id": 2, "prompt": [4], "stream": false}"#)
            .unwrap();
        assert_eq!(r.stream, Some(false));
        // Malformed option values are parse errors, not silent defaults.
        assert!(parse_request(r#"{"id": 2, "prompt": [4], "stream": 1}"#)
            .is_err());
        assert!(
            parse_request(r#"{"id": 2, "prompt": [4], "deadline_ms": -5}"#)
                .is_err()
        );
    }

    #[test]
    fn parse_priority_option() {
        let r = parse_request(
            r#"{"id": 2, "prompt": [4], "priority": "batch"}"#,
        )
        .unwrap();
        assert_eq!(r.priority, Some(Priority::Batch));
        let r = parse_request(
            r#"{"id": 2, "prompt": [4], "priority": "interactive"}"#,
        )
        .unwrap();
        assert_eq!(r.priority, Some(Priority::Interactive));
        let r = parse_request(r#"{"id": 2, "prompt": [4]}"#).unwrap();
        assert_eq!(r.priority, None);
        // Unknown classes are errors, not silent defaults.
        assert!(
            parse_request(r#"{"id": 2, "prompt": [4], "priority": "vip"}"#)
                .is_err()
        );
        assert!(parse_request(r#"{"id": 2, "prompt": [4], "priority": 3}"#)
            .is_err());
    }

    #[test]
    fn backpressure_lines_carry_retry_hint() {
        let j = Json::parse(&backpressure_line(7, "deferred: no headroom", 25))
            .unwrap();
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 7);
        assert_eq!(j.get("retry_after_ms").unwrap().as_i64().unwrap(), 25);
        assert!(j.get("error").unwrap().as_str().unwrap().starts_with("deferred"));
        assert!(j.get("stop").is_err(), "backpressure is not terminal");
    }

    #[test]
    fn preempted_frames_are_non_terminal_json() {
        let j = Json::parse(&encode_preempted(11)).unwrap();
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 11);
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "preempted");
        assert!(j.get("stop").is_err());
        assert!(j.get("error").is_err());
    }

    #[test]
    fn delta_frames_are_valid_json() {
        let line = encode_delta(9, 42, 3);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 9);
        assert_eq!(j.get("index").unwrap().as_i64().unwrap(), 3);
        let d = j.get("delta").unwrap().as_arr().unwrap();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].as_i64().unwrap(), 42);
        assert!(j.get("stop").is_err(), "deltas must not look terminal");
    }

    #[test]
    fn encode_completion_line() {
        let c = Completion {
            id: 3,
            prompt_len: 5,
            generated: vec![9, 2],
            stop: StopReason::Eos,
            ttft: Duration::from_millis(10),
            e2e: Duration::from_millis(20),
            stats: SeqStats::default(),
        };
        let line = encode_completion(&c);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 3);
        assert_eq!(j.get("stop").unwrap().as_str().unwrap(), "eos");
        assert_eq!(j.get("generated").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn error_lines_carry_optional_ids() {
        let j = Json::parse(&error_line(None, "nope")).unwrap();
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "nope");
        assert!(j.get("id").is_err());
        let j = Json::parse(&error_line(Some(9), "msg \"quoted\"")).unwrap();
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 9);
        assert!(j.get("error").unwrap().as_str().unwrap().contains("quoted"));
    }
}
