//! TCP JSON-lines serving front-end over a sharded [`EngineGroup`].
//!
//! Protocol (one JSON object per line):
//!   request:  {"id": 1, "prompt": [tok, ...], "max_new": 32}
//!   response: {"id": 1, "generated": [tok, ...], "stop": "eos",
//!              "ttft_ms": 12.3, "e2e_ms": 45.6}
//!
//! Connection I/O runs on per-connection reader threads that funnel
//! parsed requests through a channel into the serving loop, which routes
//! them across the group's engine shards and fans completions back to
//! the owning connection. Ids are rewritten internally so concurrent
//! clients cannot collide. (The offline vendor set has no tokio;
//! std::net + threads provide the same architecture.)

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::request::{Completion, Request, StopReason};
use super::shard::EngineGroup;
use super::DecodeEngine;
use crate::util::json::Json;

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let j = Json::parse(line)?;
    let id = j.get("id")?.as_i64()? as u64;
    let prompt: Vec<i32> = j
        .get("prompt")?
        .as_arr()?
        .iter()
        .map(|t| Ok(t.as_i64()? as i32))
        .collect::<Result<_>>()?;
    let max_new = j.opt("max_new").map(|v| v.as_usize()).transpose()?.unwrap_or(32);
    Ok(Request { id, prompt, max_new })
}

/// Encode one completion line.
pub fn encode_completion(c: &Completion) -> String {
    let stop = match c.stop {
        StopReason::Eos => "eos",
        StopReason::MaxNewTokens => "max_new",
        StopReason::ContextFull => "context_full",
    };
    Json::obj(vec![
        ("id", Json::Num(c.id as f64)),
        ("generated",
         Json::Arr(c.generated.iter().map(|&t| Json::Num(t as f64)).collect())),
        ("stop", Json::Str(stop.to_string())),
        ("ttft_ms", Json::Num(c.ttft.as_secs_f64() * 1e3)),
        ("e2e_ms", Json::Num(c.e2e.as_secs_f64() * 1e3)),
    ])
    .to_string()
}

struct Inflight {
    conn: Arc<Mutex<TcpStream>>,
    client_id: u64,
}

/// Write one completion back to its owning connection, restoring the
/// client's id.
fn reply(inflight: &mut std::collections::HashMap<u64, Inflight>,
         mut c: Completion) {
    if let Some(fl) = inflight.remove(&c.id) {
        c.id = fl.client_id;
        let line = encode_completion(&c);
        if let Ok(mut s) = fl.conn.lock() {
            let _ = writeln!(s, "{line}");
        }
    }
}

/// Serve forever on `addr` across the group's shards.
pub fn serve<E: DecodeEngine>(group: EngineGroup<E>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr).map_err(|e| anyhow!("bind {addr}: {e}"))?;
    eprintln!("[seerattn] serving on {addr} ({} shard{})", group.n_shards(),
              if group.n_shards() == 1 { "" } else { "s" });
    serve_on(listener, group, None)
}

/// Serve on an already-bound listener; with `limit = Some(n)` the loop
/// returns after writing `n` completions (tests bind port 0 and pass a
/// limit), printing the aggregated fleet metrics on the way out.
pub fn serve_on<E: DecodeEngine>(listener: TcpListener,
                                 mut group: EngineGroup<E>,
                                 limit: Option<usize>) -> Result<()> {
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let acceptor_stop = stop.clone();
    // Live connections, so shutdown can close them all — a client
    // mid-pipeline at exit gets EOF instead of blocking forever. Each
    // reader thread removes its entry on disconnect, so the registry
    // (and its duplicated fds) tracks only *live* connections.
    let conns: Arc<Mutex<std::collections::HashMap<u64, TcpStream>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let acceptor_conns = conns.clone();
    let (tx, rx): (Sender<(Request, Arc<Mutex<TcpStream>>)>, Receiver<_>) = channel();
    // Acceptor thread: spawns a reader thread per connection.
    std::thread::spawn(move || {
        let mut next_conn = 0u64;
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if acceptor_stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let cid = next_conn;
                    next_conn += 1;
                    match stream.try_clone() {
                        Ok(clone) => {
                            acceptor_conns.lock().unwrap().insert(cid, clone);
                        }
                        // Untracked connections could never be closed at
                        // shutdown — refuse rather than serve one.
                        Err(_) => continue,
                    }
                    let tx = tx.clone();
                    let reader_conns = acceptor_conns.clone();
                    std::thread::spawn(move || {
                        let shared =
                            Arc::new(Mutex::new(stream.try_clone().unwrap()));
                        let reader = BufReader::new(stream);
                        for line in reader.lines() {
                            let line = match line {
                                Ok(l) => l,
                                Err(_) => break,
                            };
                            if line.trim().is_empty() {
                                continue;
                            }
                            match parse_request(&line) {
                                Ok(req) => {
                                    let _ = tx.send((req, shared.clone()));
                                }
                                Err(e) => {
                                    // Through Json so the message is
                                    // escaped (parse errors quote the
                                    // missing key).
                                    let reply = Json::obj(vec![
                                        ("error", Json::Str(format!("{e}"))),
                                    ])
                                    .to_string();
                                    let mut s = shared.lock().unwrap();
                                    let _ = writeln!(s, "{reply}");
                                }
                            }
                        }
                        // Disconnect: release this connection's registry
                        // entry (and its duplicated fd).
                        reader_conns.lock().unwrap().remove(&cid);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if acceptor_stop.load(Ordering::Relaxed) {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    });

    // Serving loop: route newly arrived requests across the shards, fan
    // completed generations back to their connections. Any exit path —
    // limit reached or a shard failure — must stop the acceptor and
    // shut the group down, so errors are collected rather than
    // early-returned.
    let max_prompt = group.max_prompt_len();
    let mut inflight: std::collections::HashMap<u64, Inflight> =
        std::collections::HashMap::new();
    let mut next_id = 0u64;
    let mut served = 0usize;
    let mut failure: Option<anyhow::Error> = None;
    'serve: loop {
        // Checked at loop entry so limit = Some(0) terminates without
        // waiting for a completion that will never be counted.
        if let Some(n) = limit {
            if served >= n {
                break 'serve;
            }
        }
        while let Ok((mut req, conn)) = rx.try_recv() {
            // Reject instead of submitting: an over-long prompt would
            // panic the target shard's engine (context overflow).
            if req.prompt.len() > max_prompt {
                let reply = Json::obj(vec![
                    ("id", Json::Num(req.id as f64)),
                    ("error",
                     Json::Str(format!("prompt too long ({} > {max_prompt} tokens)",
                                       req.prompt.len()))),
                ])
                .to_string();
                if let Ok(mut s) = conn.lock() {
                    let _ = writeln!(s, "{reply}");
                }
                continue;
            }
            let client_id = req.id;
            req.id = next_id;
            inflight.insert(next_id, Inflight { conn, client_id });
            next_id += 1;
            if let Err(e) = group.submit(req) {
                failure = Some(e);
                break 'serve;
            }
        }
        match group.poll(Duration::from_millis(2)) {
            Ok(Some(c)) => {
                reply(&mut inflight, c);
                served += 1;
            }
            Ok(None) => {}
            Err(e) => {
                failure = Some(e);
                break 'serve;
            }
        }
    }
    stop.store(true, Ordering::Relaxed);
    // Requests still sitting in the parse channel were accepted but
    // never routed — tell their clients instead of going silent.
    while let Ok((req, conn)) = rx.try_recv() {
        let msg = Json::obj(vec![
            ("id", Json::Num(req.id as f64)),
            ("error", Json::Str("server shutting down".to_string())),
        ])
        .to_string();
        if let Ok(mut s) = conn.lock() {
            let _ = writeln!(s, "{msg}");
        }
    }
    // The limit counts served replies: anything already routed to a
    // shard still gets its reply before shutdown, so no accepted
    // request is silently dropped — and a shard failure during this
    // drain is surfaced exactly like one during the main loop.
    if failure.is_none() {
        while group.inflight() > 0 {
            match group.poll(Duration::from_millis(5)) {
                Ok(Some(c)) => reply(&mut inflight, c),
                Ok(None) => {}
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
    }
    let result = match failure {
        None => group.shutdown().map(|gm| eprintln!("{}", gm.report())),
        Some(e) => {
            // Best-effort teardown; the original failure is the story.
            let _ = group.shutdown();
            Err(e)
        }
    };
    // A reader thread may have parsed a request after the drain above —
    // closing every connection turns "blocked forever on read_line"
    // into an EOF for any such client (queued replies still flush:
    // TCP sends the write queue before FIN).
    for s in conns.lock().unwrap().values() {
        let _ = s.shutdown(std::net::Shutdown::Both);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SeqStats;

    #[test]
    fn parse_roundtrip() {
        let r = parse_request(r#"{"id": 7, "prompt": [1, 2, 3], "max_new": 16}"#).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new, 16);
        // default max_new
        let r = parse_request(r#"{"id": 1, "prompt": []}"#).unwrap();
        assert_eq!(r.max_new, 32);
        assert!(parse_request("{\"id\": 1}").is_err());
    }

    #[test]
    fn encode_completion_line() {
        let c = Completion {
            id: 3,
            prompt_len: 5,
            generated: vec![9, 2],
            stop: StopReason::Eos,
            ttft: Duration::from_millis(10),
            e2e: Duration::from_millis(20),
            stats: SeqStats::default(),
        };
        let line = encode_completion(&c);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 3);
        assert_eq!(j.get("stop").unwrap().as_str().unwrap(), "eos");
        assert_eq!(j.get("generated").unwrap().as_arr().unwrap().len(), 2);
    }
}
