//! TCP JSON-lines serving front-end.
//!
//! Protocol (one JSON object per line):
//!   request:  {"id": 1, "prompt": [tok, ...], "max_new": 32}
//!   response: {"id": 1, "generated": [tok, ...], "stop": "eos",
//!              "ttft_ms": 12.3, "e2e_ms": 45.6}
//!
//! The engine is single-threaded (one PJRT CPU device); the server
//! thread-pool handles connection I/O and funnels requests through a
//! channel into the engine loop, which batches them continuously. (The
//! offline vendor set has no tokio; std::net + threads provide the same
//! architecture.)

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::engine::Engine;
use super::request::{Completion, Request, StopReason};
use crate::util::json::Json;

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let j = Json::parse(line)?;
    let id = j.get("id")?.as_i64()? as u64;
    let prompt: Vec<i32> = j
        .get("prompt")?
        .as_arr()?
        .iter()
        .map(|t| Ok(t.as_i64()? as i32))
        .collect::<Result<_>>()?;
    let max_new = j.opt("max_new").map(|v| v.as_usize()).transpose()?.unwrap_or(32);
    Ok(Request { id, prompt, max_new })
}

/// Encode one completion line.
pub fn encode_completion(c: &Completion) -> String {
    let stop = match c.stop {
        StopReason::Eos => "eos",
        StopReason::MaxNewTokens => "max_new",
        StopReason::ContextFull => "context_full",
    };
    Json::obj(vec![
        ("id", Json::Num(c.id as f64)),
        ("generated",
         Json::Arr(c.generated.iter().map(|&t| Json::Num(t as f64)).collect())),
        ("stop", Json::Str(stop.to_string())),
        ("ttft_ms", Json::Num(c.ttft.as_secs_f64() * 1e3)),
        ("e2e_ms", Json::Num(c.e2e.as_secs_f64() * 1e3)),
    ])
    .to_string()
}

struct Inflight {
    conn: Arc<Mutex<TcpStream>>,
    client_id: u64,
}

/// Serve forever on `addr`. Each connection may pipeline requests; ids
/// are rewritten internally so concurrent clients cannot collide.
pub fn serve(mut engine: Engine, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr).map_err(|e| anyhow!("bind {addr}: {e}"))?;
    listener.set_nonblocking(true)?;
    eprintln!("[seerattn] serving on {addr} (policy {})", engine.ecfg.policy.name());
    let (tx, rx): (Sender<(Request, Arc<Mutex<TcpStream>>)>, Receiver<_>) = channel();
    // Acceptor thread: spawns a reader thread per connection.
    std::thread::spawn(move || loop {
        match listener.accept() {
            Ok((stream, _)) => {
                let tx = tx.clone();
                std::thread::spawn(move || {
                    let shared = Arc::new(Mutex::new(stream.try_clone().unwrap()));
                    let reader = BufReader::new(stream);
                    for line in reader.lines() {
                        let line = match line {
                            Ok(l) => l,
                            Err(_) => break,
                        };
                        if line.trim().is_empty() {
                            continue;
                        }
                        match parse_request(&line) {
                            Ok(req) => {
                                let _ = tx.send((req, shared.clone()));
                            }
                            Err(e) => {
                                let mut s = shared.lock().unwrap();
                                let _ = writeln!(s, "{{\"error\": \"{e}\"}}");
                            }
                        }
                    }
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => break,
        }
    });

    // Engine loop: admit from the channel, step, push completions back.
    let mut inflight: std::collections::HashMap<u64, Inflight> =
        std::collections::HashMap::new();
    let mut next_id = 0u64;
    loop {
        // Drain newly arrived requests.
        while let Ok((mut req, conn)) = rx.try_recv() {
            let client_id = req.id;
            req.id = next_id;
            inflight.insert(next_id, Inflight { conn, client_id });
            next_id += 1;
            engine.submit(req);
        }
        if engine.idle() {
            std::thread::sleep(Duration::from_millis(2));
            continue;
        }
        for mut c in engine.step()? {
            if let Some(fl) = inflight.remove(&c.id) {
                c.id = fl.client_id;
                let line = encode_completion(&c);
                if let Ok(mut s) = fl.conn.lock() {
                    let _ = writeln!(s, "{line}");
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SeqStats;

    #[test]
    fn parse_roundtrip() {
        let r = parse_request(r#"{"id": 7, "prompt": [1, 2, 3], "max_new": 16}"#).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new, 16);
        // default max_new
        let r = parse_request(r#"{"id": 1, "prompt": []}"#).unwrap();
        assert_eq!(r.max_new, 32);
        assert!(parse_request("{\"id\": 1}").is_err());
    }

    #[test]
    fn encode_completion_line() {
        let c = Completion {
            id: 3,
            prompt_len: 5,
            generated: vec![9, 2],
            stop: StopReason::Eos,
            ttft: Duration::from_millis(10),
            e2e: Duration::from_millis(20),
            stats: SeqStats::default(),
        };
        let line = encode_completion(&c);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 3);
        assert_eq!(j.get("stop").unwrap().as_str().unwrap(), "eos");
        assert_eq!(j.get("generated").unwrap().as_arr().unwrap().len(), 2);
    }
}
