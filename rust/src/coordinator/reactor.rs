//! Minimal single-threaded epoll reactor for the serving front-end.
//!
//! The offline vendor set has no `mio`/`tokio`/`libc` crate, so this
//! wraps the four raw syscalls the front-end needs — `epoll_create1`,
//! `epoll_ctl`, `epoll_wait`, `close` — behind direct `extern "C"`
//! declarations (they resolve against the C library `std` already links;
//! no new dependency). Everything above the fd level stays in safe std:
//! sockets are `TcpListener`/`TcpStream` in non-blocking mode and the
//! reactor only ever sees their raw fds, which it neither duplicates nor
//! owns — callers keep the socket alive for as long as it is registered,
//! and closing the socket removes it from the interest set.
//!
//! Linux-only by construction (epoll is the production serving target;
//! CI runs on Linux). The API is deliberately tiny: register / modify /
//! deregister an fd with a `u64` token and read/write interest, then
//! `wait` for a batch of [`Event`]s.
//!
//! [`WakeFd`] vendors `eventfd` the same way: an 8-byte counter fd that
//! other threads bump to wake a reactor parked in `wait` with no timeout
//! tick — the kernel-side add is atomic, so `signal()` is safe from any
//! thread while the owning reactor holds the fd registered.

use std::io;
use std::os::unix::io::RawFd;
use std::time::Duration;

use anyhow::{anyhow, Result};

const EPOLL_CLOEXEC: i32 = 0o2000000;
const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;

const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;
const EPOLLRDHUP: u32 = 0x2000;

/// Mirror of the kernel's `struct epoll_event`. x86_64 is the one ABI
/// where the kernel declares it packed.
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

const EFD_NONBLOCK: i32 = 0o4000;
const EFD_CLOEXEC: i32 = 0o2000000;

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32,
                  timeout_ms: i32) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn close(fd: i32) -> i32;
}

/// A vendored `eventfd` wakeup handle.
///
/// The owning reactor registers `as_raw_fd()` for read interest; any
/// other thread calls [`WakeFd::signal`] to make the next (or current)
/// `epoll_wait` return immediately. The fd is a saturating 64-bit
/// counter: concurrent signals coalesce into one readable event, and
/// [`WakeFd::drain`] resets it so level-triggered polling goes quiet
/// again. Both ends are a single syscall — no locks, no pipes.
pub struct WakeFd {
    fd: RawFd,
}

impl WakeFd {
    pub fn new() -> Result<WakeFd> {
        let fd = unsafe { eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC) };
        if fd < 0 {
            return Err(anyhow!("eventfd: {}", io::Error::last_os_error()));
        }
        Ok(WakeFd { fd })
    }

    /// Wake the reactor watching this fd. Never blocks: if the counter
    /// is already saturated the write fails with EAGAIN, but a wakeup is
    /// pending in that case by definition, so the error is ignored.
    pub fn signal(&self) {
        let one: u64 = 1;
        unsafe {
            write(self.fd, &one as *const u64 as *const u8,
                  std::mem::size_of::<u64>());
        }
    }

    /// Reset the counter after a wakeup so the fd stops reading as ready.
    /// Ignores EAGAIN (someone else — or nobody — already drained it).
    pub fn drain(&self) {
        let mut buf: u64 = 0;
        unsafe {
            read(self.fd, &mut buf as *mut u64 as *mut u8,
                 std::mem::size_of::<u64>());
        }
    }

    pub fn as_raw_fd(&self) -> RawFd {
        self.fd
    }
}

impl Drop for WakeFd {
    fn drop(&mut self) {
        unsafe {
            close(self.fd);
        }
    }
}

/// What a registered fd wants to be woken for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest { readable: true, writable: false };
    pub const READ_WRITE: Interest = Interest { readable: true, writable: true };

    fn bits(self) -> u32 {
        let mut e = 0;
        if self.readable {
            // RDHUP only alongside read interest: a half-closed peer we
            // are still writing replies to must not level-trigger wakeups
            // forever.
            e |= EPOLLIN | EPOLLRDHUP;
        }
        if self.writable {
            e |= EPOLLOUT;
        }
        e
    }
}

/// One readiness notification. `readable` includes error/hangup states so
/// a dead peer always surfaces through the read path (as EOF or an I/O
/// error) rather than being silently dropped.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

/// A thin owner of one epoll instance.
pub struct Reactor {
    epfd: i32,
    buf: Vec<EpollEvent>,
}

impl Reactor {
    pub fn new() -> Result<Reactor> {
        let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(anyhow!("epoll_create1: {}", io::Error::last_os_error()));
        }
        Ok(Reactor { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 64] })
    }

    fn ctl(&self, op: i32, fd: RawFd, ev: Option<EpollEvent>) -> Result<()> {
        let mut ev = ev;
        let ptr = match ev.as_mut() {
            Some(e) => e as *mut EpollEvent,
            None => std::ptr::null_mut(),
        };
        let rc = unsafe { epoll_ctl(self.epfd, op, fd, ptr) };
        if rc < 0 {
            return Err(anyhow!("epoll_ctl(op={op}, fd={fd}): {}",
                               io::Error::last_os_error()));
        }
        Ok(())
    }

    /// Start watching `fd`; events for it carry `token`.
    pub fn register(&self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd,
                 Some(EpollEvent { events: interest.bits(), data: token }))
    }

    /// Change the interest set of an already-registered fd.
    pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd,
                 Some(EpollEvent { events: interest.bits(), data: token }))
    }

    /// Stop watching `fd`. Harmless to call right before closing it (the
    /// kernel also drops closed fds from the interest set on its own when
    /// no duplicate remains).
    pub fn deregister(&self, fd: RawFd) -> Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, None)
    }

    /// Block up to `timeout` and append one [`Event`] per ready fd to
    /// `out` (cleared first). Retries on EINTR.
    pub fn wait(&mut self, timeout: Duration, out: &mut Vec<Event>) -> Result<()> {
        out.clear();
        let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
        let n = loop {
            let n = unsafe {
                epoll_wait(self.epfd, self.buf.as_mut_ptr(),
                           self.buf.len() as i32, ms)
            };
            if n >= 0 {
                break n as usize;
            }
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                continue;
            }
            return Err(anyhow!("epoll_wait: {err}"));
        };
        for ev in self.buf.iter().take(n).copied() {
            let bits = ev.events;
            out.push(Event {
                token: ev.data,
                readable: bits & (EPOLLIN | EPOLLRDHUP | EPOLLHUP | EPOLLERR) != 0,
                writable: bits & EPOLLOUT != 0,
            });
        }
        // A full batch means there may be more ready fds than the buffer
        // holds; grow so the next wait sees them all at once.
        if n == self.buf.len() {
            let len = self.buf.len() * 2;
            self.buf.resize(len, EpollEvent { events: 0, data: 0 });
        }
        Ok(())
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        unsafe {
            close(self.epfd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::time::Instant;

    #[test]
    fn reports_accept_and_read_readiness() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let mut r = Reactor::new().unwrap();
        r.register(listener.as_raw_fd(), 1, Interest::READ).unwrap();

        let mut events = Vec::new();
        r.wait(Duration::from_millis(1), &mut events).unwrap();
        assert!(events.is_empty(), "nothing connected yet");

        let mut client = TcpStream::connect(addr).unwrap();
        // The pending connection must surface as readability on the
        // listener within a generous deadline.
        let mut accepted = None;
        for _ in 0..500 {
            r.wait(Duration::from_millis(10), &mut events).unwrap();
            if events.iter().any(|e| e.token == 1 && e.readable) {
                let (s, _) = listener.accept().unwrap();
                s.set_nonblocking(true).unwrap();
                accepted = Some(s);
                break;
            }
        }
        let accepted = accepted.expect("listener never became readable");
        r.register(accepted.as_raw_fd(), 2, Interest::READ_WRITE).unwrap();

        client.write_all(b"ping\n").unwrap();
        let mut saw_read = false;
        let mut saw_write = false;
        for _ in 0..500 {
            r.wait(Duration::from_millis(10), &mut events).unwrap();
            for e in &events {
                if e.token == 2 {
                    saw_read |= e.readable;
                    saw_write |= e.writable;
                }
            }
            if saw_read && saw_write {
                break;
            }
        }
        assert!(saw_read, "conn never readable after client write");
        assert!(saw_write, "fresh conn never writable");

        // Dropping write interest must stop writable notifications.
        r.modify(accepted.as_raw_fd(), 2, Interest::READ).unwrap();
        r.wait(Duration::from_millis(20), &mut events).unwrap();
        assert!(events.iter().all(|e| e.token != 2 || !e.writable),
                "writable after interest dropped: {events:?}");
        r.deregister(accepted.as_raw_fd()).unwrap();
    }

    #[test]
    fn wakefd_signals_across_threads_and_drains_quiet() {
        let mut r = Reactor::new().unwrap();
        let wake = std::sync::Arc::new(WakeFd::new().unwrap());
        r.register(wake.as_raw_fd(), 7, Interest::READ).unwrap();

        let mut events = Vec::new();
        r.wait(Duration::from_millis(1), &mut events).unwrap();
        assert!(events.is_empty(), "fresh eventfd must be quiet");

        // Signal from another thread after a delay: the reactor must be
        // woken out of a long wait, not at the timeout.
        let w = wake.clone();
        let t0 = Instant::now();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            w.signal();
            w.signal(); // coalesces with the first
        });
        r.wait(Duration::from_secs(10), &mut events).unwrap();
        h.join().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(5),
                "wait did not wake on signal");
        assert!(events.iter().any(|e| e.token == 7 && e.readable),
                "no wake event: {events:?}");

        // Level-triggered: still readable until drained, quiet after.
        r.wait(Duration::from_millis(1), &mut events).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable),
                "undrained eventfd must stay ready");
        wake.drain();
        r.wait(Duration::from_millis(1), &mut events).unwrap();
        assert!(events.is_empty(), "drained eventfd must be quiet");
        wake.drain(); // double drain is a harmless EAGAIN
    }
}
