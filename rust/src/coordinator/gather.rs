//! The gather stage of the decode hot path, factored out of the engine
//! so the serial and parallel variants share one implementation and are
//! testable without PJRT.
//!
//! Staging buffers are laid out batch-row-major, so each slot's writes
//! (K/V rows, mask, dirty extents) land in a disjoint contiguous chunk of
//! the [`StagingArena`] set. That partition is what makes the parallel
//! variant safe: jobs are validated to target strictly-ascending,
//! in-range rows, and each worker carves its own chunk out of the shared
//! buffers by row index — bit-identical output to the serial loop.
//!
//! Parallelism runs on a persistent [`GatherPool`]: worker threads are
//! spawned once (engine lifetime) and woken per call, replacing the
//! per-step `thread::scope` spawn of the previous design. Work is
//! claimed item-by-item under the pool mutex (jobs are coarse — one
//! slot's full gather — so claim overhead is noise), and the caller
//! participates too, so `threads = n` means `n` lanes, not `n + 1`.
//! Neither the serial nor the parallel path allocates: the old per-call
//! work-list `Vec` is gone, which keeps the steady-state
//! zero-allocation invariant across both paths.
//!
//! [`StagingArena`]: super::arena::StagingArena

use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::kvcache::{PagedKvPool, SeqKv};
use crate::sparse::policy::{SelKind, SelectionBuf};

/// One slot's gather work: its staging row index, KV block table, and
/// block selection. The dense gathers stage the whole cache and ignore
/// `sel` (dense slots carry a `SelKind::Dense` buf anyway); one job type
/// keeps the engine's job construction identical across both branches.
#[derive(Clone, Copy)]
pub struct GatherJob<'a> {
    /// Batch row in the staging set (= slot index).
    pub row: usize,
    pub kv: &'a SeqKv,
    /// Block selection; read only by the sparse gathers.
    pub sel: &'a SelectionBuf,
}

/// Geometry of a sparse staging set `[b, heads, t_cap, dh]`.
#[derive(Debug, Clone, Copy)]
pub struct SparseGeom {
    pub heads: usize,
    /// GQA group size (query heads per KV head).
    pub group: usize,
    /// Staging is per query head (Quest) rather than per KV head.
    pub per_head: bool,
    pub block_size: usize,
    pub t_cap: usize,
    pub dh: usize,
}

/// Geometry of a dense staging set `[b, hkv, max_seq, dh]`.
#[derive(Debug, Clone, Copy)]
pub struct DenseGeom {
    pub hkv: usize,
    pub block_size: usize,
    pub max_seq: usize,
    pub dh: usize,
}

/// The selection row feeding staging head-row `hr` — a Shared selection
/// is indexed by the GQA group when staging is per query head.
pub fn selected_row<'a>(sel: &'a SelectionBuf, hr: usize, per_head: bool,
                        group: usize) -> &'a [i32] {
    match sel.kind() {
        SelKind::Shared if per_head => &sel.rows()[hr / group],
        SelKind::Shared | SelKind::PerHead => &sel.rows()[hr],
        SelKind::Dense => unreachable!("dense slots use the dense gather"),
    }
}

/// Gather one slot's selected blocks into its chunk of a sparse staging
/// set. `k`/`v` are the slot's `[heads, t_cap, dh]` chunk, `mask` its
/// `[heads, t_cap]` chunk, `dirty` its `[heads]` extents. Allocation-free.
pub fn gather_one_sparse(pool: &PagedKvPool, job: &GatherJob, geom: &SparseGeom,
                         k: &mut [f32], v: &mut [f32], mask: &mut [f32],
                         dirty: &mut [usize]) {
    let SparseGeom { heads, group, per_head, block_size, t_cap, dh } = *geom;
    for hr in 0..heads {
        let row = selected_row(job.sel, hr, per_head, group);
        let kv_head = if per_head { hr / group } else { hr };
        let mut cursor = 0usize;
        for &j in row {
            let n = job.kv.tokens_in_block(j as usize, block_size);
            let pg = job.kv.pages[j as usize];
            let off = (hr * t_cap + cursor) * dh;
            pool.gather_block(pg, kv_head, n, &mut k[off..off + n * dh],
                              &mut v[off..off + n * dh]);
            let moff = hr * t_cap + cursor;
            crate::util::simd::fill(&mut mask[moff..moff + n], 1.0);
            cursor += n;
        }
        dirty[hr] = cursor;
    }
}

/// Gather one slot's full cache into its chunk of a dense staging set.
/// `seq_len` is the slot's single-element chunk. Allocation-free.
pub fn gather_one_dense(pool: &PagedKvPool, job: &GatherJob, geom: &DenseGeom,
                        k: &mut [f32], v: &mut [f32], seq_len: &mut [i32],
                        dirty: &mut [usize]) {
    let DenseGeom { hkv, block_size, max_seq, dh } = *geom;
    seq_len[0] = job.kv.len as i32;
    for h in 0..hkv {
        for (blk, &pg) in job.kv.pages.iter().enumerate() {
            let n = job.kv.tokens_in_block(blk, block_size);
            let off = (h * max_seq + blk * block_size) * dh;
            pool.gather_block(pg, h, n, &mut k[off..off + n * dh],
                              &mut v[off..off + n * dh]);
        }
        dirty[h] = job.kv.len;
    }
}

// ---------------------------------------------------------------------
// Persistent worker pool.
// ---------------------------------------------------------------------

/// A type-erased borrow of the current call's `Fn(usize)` item closure.
/// Only alive while [`GatherPool::run`] is on the caller's stack: workers
/// touch it strictly between the task being installed and the caller
/// observing "all items claimed, no lane executing" (both under the pool
/// mutex), and `run` does not return — or unwind — before that point.
#[derive(Clone, Copy)]
struct TaskRef {
    data: *const (),
    call: unsafe fn(*const (), usize),
}

// The raw pointer crosses into worker threads; validity is guaranteed by
// the run() protocol above.
unsafe impl Send for TaskRef {}

unsafe fn call_erased<F: Fn(usize) + Sync>(data: *const (), i: usize) {
    (*(data as *const F))(i)
}

struct PoolState {
    task: Option<TaskRef>,
    n_items: usize,
    /// Next unclaimed item index (forced to `n_items` on a lane panic so
    /// no further claims touch a possibly-dead closure).
    next: usize,
    /// Lanes currently inside the item closure.
    executing: usize,
    /// Some lane's item closure panicked during the current task.
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Signalled when a task is installed (or shutdown begins).
    start: Condvar,
    /// Signalled when the last item of a task completes.
    done: Condvar,
}

/// Persistent gather fan-out pool: `threads - 1` worker threads plus the
/// calling thread cooperatively claim item indices per [`run`] call.
/// Spawned once, reused every decode step — no per-call thread spawn,
/// no per-call allocation.
///
/// [`run`]: GatherPool::run
pub struct GatherPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
}

impl GatherPool {
    /// A pool delivering `threads` concurrent lanes (the caller counts
    /// as one, so this spawns `threads - 1` workers).
    pub fn new(threads: usize) -> GatherPool {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                task: None,
                n_items: 0,
                next: 0,
                executing: 0,
                panicked: false,
                shutdown: false,
            }),
            start: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = (1..threads.max(1))
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("gather-{i}"))
                    .spawn(move || Self::worker_main(&sh))
                    .expect("spawn gather worker")
            })
            .collect();
        GatherPool { shared, workers }
    }

    /// Concurrent lanes including the caller.
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Default lane count for `EngineConfig::gather_threads = 0` (auto):
    /// half the logical cores, clamped to `[1, 4]`. Gather jobs are
    /// coarse (one slot's full staged copy) and memory-bandwidth-bound,
    /// so lanes beyond ~4 mostly contend on the memory bus; half the
    /// cores leaves room for the serving reactor and sibling shards.
    /// See PERF.md "Gather fan-out default" for the measurement
    /// protocol behind this choice.
    pub fn default_lanes() -> usize {
        std::thread::available_parallelism()
            .map(|n| (n.get() / 2).clamp(1, 4))
            .unwrap_or(1)
    }

    fn worker_main(shared: &PoolShared) {
        let mut st = shared.state.lock().unwrap();
        loop {
            if st.shutdown {
                return;
            }
            if let Some(task) = st.task {
                if st.next < st.n_items {
                    let i = st.next;
                    st.next += 1;
                    st.executing += 1;
                    drop(st);
                    // Catch panics so a failing item cannot leave the
                    // caller blocked on `done` forever; the caller
                    // re-raises after the task drains.
                    let r = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| unsafe {
                            (task.call)(task.data, i)
                        }));
                    st = shared.state.lock().unwrap();
                    st.executing -= 1;
                    if r.is_err() {
                        st.panicked = true;
                        st.next = st.n_items;
                    }
                    shared.done.notify_all();
                    continue;
                }
            }
            st = shared.start.wait(st).unwrap();
        }
    }

    /// Run `f(0..n)` across the pool's lanes; returns once every call
    /// has completed. `f` borrows from the caller's stack — the erased
    /// pointer never outlives this frame: every exit path (including a
    /// panicking item, which is caught on all lanes and re-raised here)
    /// waits until no lane is still inside `f` before the task is
    /// cleared and the frame unwinds.
    pub fn run<F: Fn(usize) + Sync>(&self, n: usize, f: &F) {
        if n == 0 {
            return;
        }
        let task = TaskRef { data: f as *const F as *const (), call: call_erased::<F> };
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.task.is_none(), "GatherPool::run re-entered");
            st.task = Some(task);
            st.n_items = n;
            st.next = 0;
            st.executing = 0;
            st.panicked = false;
            self.shared.start.notify_all();
        }
        // The caller is a lane too: claim items alongside the workers.
        let mut caller_panic = None;
        loop {
            let i = {
                let mut st = self.shared.state.lock().unwrap();
                if st.next >= st.n_items {
                    break;
                }
                let i = st.next;
                st.next += 1;
                st.executing += 1;
                i
            };
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i)));
            let mut st = self.shared.state.lock().unwrap();
            st.executing -= 1;
            if r.is_err() {
                st.panicked = true;
                st.next = st.n_items;
                caller_panic = r.err();
            }
            self.shared.done.notify_all();
        }
        // Task is finished when every item is claimed (or skipped after
        // a panic) and no lane is still running one.
        let mut st = self.shared.state.lock().unwrap();
        while st.next < st.n_items || st.executing > 0 {
            st = self.shared.done.wait(st).unwrap();
        }
        st.task = None;
        let panicked = st.panicked;
        drop(st);
        if let Some(p) = caller_panic {
            std::panic::resume_unwind(p);
        }
        assert!(!panicked, "a gather pool worker lane panicked");
    }
}

impl Drop for GatherPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.start.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Validate that jobs target strictly-ascending, in-range staging rows —
/// the disjointness invariant the parallel chunk-carving relies on. A
/// violated invariant would silently leave staging rows zeroed
/// (attention over an empty selection), so it is a hard assert.
fn check_rows<'a, F: Fn(usize) -> GatherJob<'a>>(n_jobs: usize, job_at: &F,
                                                 n_rows: usize) {
    let mut prev: Option<usize> = None;
    for idx in 0..n_jobs {
        let r = job_at(idx).row;
        assert!(r < n_rows && prev.map(|p| p < r).unwrap_or(true),
                "gather jobs must target ascending staging rows < {n_rows}");
        prev = Some(r);
    }
}

/// Sparse gather over `n_jobs` slots (`job_at(i)` yields each job),
/// fanned out over `par`'s persistent lanes when given (serial when
/// `None` or there is one job). Output is bit-identical to calling
/// [`gather_one_sparse`] per job; neither path allocates.
#[allow(clippy::too_many_arguments)]
pub fn gather_sparse_into<'a, F>(pool: &PagedKvPool, n_jobs: usize, job_at: &F,
                                 geom: &SparseGeom, k: &mut [f32],
                                 v: &mut [f32], mask: &mut [f32],
                                 dirty: &mut [usize], par: Option<&GatherPool>)
where
    F: Fn(usize) -> GatherJob<'a> + Sync,
{
    let row_kv = geom.heads * geom.t_cap * geom.dh;
    let row_mask = geom.heads * geom.t_cap;
    let row_dirty = geom.heads;
    match par {
        Some(gp) if n_jobs > 1 => {
            check_rows(n_jobs, job_at, k.len() / row_kv);
            let (kb, vb) = (k.as_mut_ptr() as usize, v.as_mut_ptr() as usize);
            let (mb, db) = (mask.as_mut_ptr() as usize, dirty.as_mut_ptr() as usize);
            let worker = |idx: usize| {
                let job = job_at(idx);
                let r = job.row;
                // Safe: rows are validated distinct and in range, so
                // each lane writes a disjoint chunk of the buffers the
                // caller exclusively borrows across this call.
                let (kc, vc, mc, dc) = unsafe {
                    (std::slice::from_raw_parts_mut(
                         (kb as *mut f32).add(r * row_kv), row_kv),
                     std::slice::from_raw_parts_mut(
                         (vb as *mut f32).add(r * row_kv), row_kv),
                     std::slice::from_raw_parts_mut(
                         (mb as *mut f32).add(r * row_mask), row_mask),
                     std::slice::from_raw_parts_mut(
                         (db as *mut usize).add(r * row_dirty), row_dirty))
                };
                gather_one_sparse(pool, &job, geom, kc, vc, mc, dc);
            };
            gp.run(n_jobs, &worker);
        }
        _ => {
            for idx in 0..n_jobs {
                let job = job_at(idx);
                let r = job.row;
                gather_one_sparse(pool, &job, geom,
                                  &mut k[r * row_kv..(r + 1) * row_kv],
                                  &mut v[r * row_kv..(r + 1) * row_kv],
                                  &mut mask[r * row_mask..(r + 1) * row_mask],
                                  &mut dirty[r * row_dirty..(r + 1) * row_dirty]);
            }
        }
    }
}

/// Dense gather over many slots; same contract as [`gather_sparse_into`]
/// but staging the full cache per slot (`seq_len` is `[b]`).
#[allow(clippy::too_many_arguments)]
pub fn gather_dense_into<'a, F>(pool: &PagedKvPool, n_jobs: usize, job_at: &F,
                                geom: &DenseGeom, k: &mut [f32], v: &mut [f32],
                                seq_len: &mut [i32], dirty: &mut [usize],
                                par: Option<&GatherPool>)
where
    F: Fn(usize) -> GatherJob<'a> + Sync,
{
    let row_kv = geom.hkv * geom.max_seq * geom.dh;
    let row_dirty = geom.hkv;
    match par {
        Some(gp) if n_jobs > 1 => {
            check_rows(n_jobs, job_at, k.len() / row_kv);
            let (kb, vb) = (k.as_mut_ptr() as usize, v.as_mut_ptr() as usize);
            let (sb, db) =
                (seq_len.as_mut_ptr() as usize, dirty.as_mut_ptr() as usize);
            let worker = |idx: usize| {
                let job = job_at(idx);
                let r = job.row;
                let (kc, vc, sc, dc) = unsafe {
                    (std::slice::from_raw_parts_mut(
                         (kb as *mut f32).add(r * row_kv), row_kv),
                     std::slice::from_raw_parts_mut(
                         (vb as *mut f32).add(r * row_kv), row_kv),
                     std::slice::from_raw_parts_mut((sb as *mut i32).add(r), 1),
                     std::slice::from_raw_parts_mut(
                         (db as *mut usize).add(r * row_dirty), row_dirty))
                };
                gather_one_dense(pool, &job, geom, kc, vc, sc, dc);
            };
            gp.run(n_jobs, &worker);
        }
        _ => {
            for idx in 0..n_jobs {
                let job = job_at(idx);
                let r = job.row;
                gather_one_dense(pool, &job, geom,
                                 &mut k[r * row_kv..(r + 1) * row_kv],
                                 &mut v[r * row_kv..(r + 1) * row_kv],
                                 &mut seq_len[r..r + 1],
                                 &mut dirty[r * row_dirty..(r + 1) * row_dirty]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_every_item_exactly_once() {
        let pool = GatherPool::new(3);
        assert_eq!(pool.threads(), 3);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        for round in 0..50 {
            let f = |i: usize| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            };
            pool.run(hits.len(), &f);
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), round + 1, "item {i}");
            }
        }
    }

    #[test]
    fn pool_of_one_degenerates_to_caller_only() {
        let pool = GatherPool::new(1);
        assert_eq!(pool.threads(), 1);
        let sum = AtomicUsize::new(0);
        let f = |i: usize| {
            sum.fetch_add(i + 1, Ordering::SeqCst);
        };
        pool.run(10, &f);
        assert_eq!(sum.load(Ordering::SeqCst), 55);
        pool.run(0, &f); // empty call is a no-op, not a hang
        assert_eq!(sum.load(Ordering::SeqCst), 55);
    }
}
