//! The gather stage of the decode hot path, factored out of the engine
//! so the serial and scoped-thread parallel variants share one
//! implementation and are testable without PJRT.
//!
//! Staging buffers are laid out batch-row-major, so each slot's writes
//! (K/V rows, mask, dirty extents) land in a disjoint contiguous chunk of
//! the [`StagingArena`] set. That partition is exactly what makes the
//! parallel variant safe: the chunks are split with `chunks_mut` and each
//! scoped thread owns a distinct set of slots — bit-identical output to
//! the serial loop, no synchronisation beyond the scope join.
//!
//! The serial entry points (`gather_one_sparse` / `gather_one_dense`)
//! take the slot's chunk directly and allocate nothing, preserving the
//! zero-allocation steady-state invariant. The parallel entry points
//! build a small per-call work list (one slice tuple per active slot) —
//! that allocation is the explicit price of fanning out, paid only when
//! `threads > 1`.
//!
//! [`StagingArena`]: super::arena::StagingArena

use crate::kvcache::{PagedKvPool, SeqKv};
use crate::sparse::policy::{SelKind, SelectionBuf};

/// One slot's gather work: its staging row index, KV block table, and
/// block selection. The dense gathers stage the whole cache and ignore
/// `sel` (dense slots carry a `SelKind::Dense` buf anyway); one job type
/// keeps the engine's job construction identical across both branches.
pub struct GatherJob<'a> {
    /// Batch row in the staging set (= slot index).
    pub row: usize,
    pub kv: &'a SeqKv,
    /// Block selection; read only by the sparse gathers.
    pub sel: &'a SelectionBuf,
}

/// Geometry of a sparse staging set `[b, heads, t_cap, dh]`.
#[derive(Debug, Clone, Copy)]
pub struct SparseGeom {
    pub heads: usize,
    /// GQA group size (query heads per KV head).
    pub group: usize,
    /// Staging is per query head (Quest) rather than per KV head.
    pub per_head: bool,
    pub block_size: usize,
    pub t_cap: usize,
    pub dh: usize,
}

/// Geometry of a dense staging set `[b, hkv, max_seq, dh]`.
#[derive(Debug, Clone, Copy)]
pub struct DenseGeom {
    pub hkv: usize,
    pub block_size: usize,
    pub max_seq: usize,
    pub dh: usize,
}

/// The selection row feeding staging head-row `hr` — a Shared selection
/// is indexed by the GQA group when staging is per query head.
pub fn selected_row<'a>(sel: &'a SelectionBuf, hr: usize, per_head: bool,
                        group: usize) -> &'a [i32] {
    match sel.kind() {
        SelKind::Shared if per_head => &sel.rows()[hr / group],
        SelKind::Shared | SelKind::PerHead => &sel.rows()[hr],
        SelKind::Dense => unreachable!("dense slots use the dense gather"),
    }
}

/// Gather one slot's selected blocks into its chunk of a sparse staging
/// set. `k`/`v` are the slot's `[heads, t_cap, dh]` chunk, `mask` its
/// `[heads, t_cap]` chunk, `dirty` its `[heads]` extents. Allocation-free.
pub fn gather_one_sparse(pool: &PagedKvPool, job: &GatherJob, geom: &SparseGeom,
                         k: &mut [f32], v: &mut [f32], mask: &mut [f32],
                         dirty: &mut [usize]) {
    let SparseGeom { heads, group, per_head, block_size, t_cap, dh } = *geom;
    for hr in 0..heads {
        let row = selected_row(job.sel, hr, per_head, group);
        let kv_head = if per_head { hr / group } else { hr };
        let mut cursor = 0usize;
        for &j in row {
            let n = job.kv.tokens_in_block(j as usize, block_size);
            let pg = job.kv.pages[j as usize];
            let off = (hr * t_cap + cursor) * dh;
            pool.gather_block(pg, kv_head, n, &mut k[off..off + n * dh],
                              &mut v[off..off + n * dh]);
            let moff = hr * t_cap + cursor;
            mask[moff..moff + n].fill(1.0);
            cursor += n;
        }
        dirty[hr] = cursor;
    }
}

/// Gather one slot's full cache into its chunk of a dense staging set.
/// `seq_len` is the slot's single-element chunk. Allocation-free.
pub fn gather_one_dense(pool: &PagedKvPool, job: &GatherJob, geom: &DenseGeom,
                        k: &mut [f32], v: &mut [f32], seq_len: &mut [i32],
                        dirty: &mut [usize]) {
    let DenseGeom { hkv, block_size, max_seq, dh } = *geom;
    seq_len[0] = job.kv.len as i32;
    for h in 0..hkv {
        for (blk, &pg) in job.kv.pages.iter().enumerate() {
            let n = job.kv.tokens_in_block(blk, block_size);
            let off = (h * max_seq + blk * block_size) * dh;
            pool.gather_block(pg, h, n, &mut k[off..off + n * dh],
                              &mut v[off..off + n * dh]);
        }
        dirty[h] = job.kv.len;
    }
}

/// Split per-row chunks of a staging set and pair them with the jobs
/// writing them. Jobs must be sorted ascending by `row`.
macro_rules! build_work {
    ($jobs:expr, $row_kv:expr, $row_aux:expr, $row_dirty:expr,
     $k:expr, $v:expr, $aux:expr, $dirty:expr) => {{
        let mut work = Vec::with_capacity($jobs.len());
        let mut jobs = $jobs.iter().peekable();
        let iter = $k
            .chunks_mut($row_kv)
            .zip($v.chunks_mut($row_kv))
            .zip($aux.chunks_mut($row_aux))
            .zip($dirty.chunks_mut($row_dirty))
            .enumerate();
        for (r, (((kc, vc), ac), dc)) in iter {
            if jobs.peek().map(|j| j.row) == Some(r) {
                work.push((jobs.next().unwrap(), kc, vc, ac, dc));
            }
        }
        // Hard assert: an unmatched job means rows were unsorted or out
        // of range, and silently skipping one would leave its staging
        // rows zeroed — attention over an empty selection, no error.
        assert!(jobs.next().is_none(),
                "gather jobs must be sorted ascending by row and in range");
        work
    }};
}

/// Sparse gather over many slots, fanned out over up to `threads` scoped
/// threads (serial when `threads <= 1` or there is one job). Output is
/// bit-identical to calling [`gather_one_sparse`] per job.
#[allow(clippy::too_many_arguments)]
pub fn gather_sparse_into(pool: &PagedKvPool, jobs: &[GatherJob],
                          geom: &SparseGeom, k: &mut [f32], v: &mut [f32],
                          mask: &mut [f32], dirty: &mut [usize],
                          threads: usize) {
    let row_kv = geom.heads * geom.t_cap * geom.dh;
    let row_mask = geom.heads * geom.t_cap;
    if threads <= 1 || jobs.len() <= 1 {
        for job in jobs {
            let r = job.row;
            gather_one_sparse(pool, job, geom,
                              &mut k[r * row_kv..(r + 1) * row_kv],
                              &mut v[r * row_kv..(r + 1) * row_kv],
                              &mut mask[r * row_mask..(r + 1) * row_mask],
                              &mut dirty[r * geom.heads..(r + 1) * geom.heads]);
        }
        return;
    }
    let mut work = build_work!(jobs, row_kv, row_mask, geom.heads, k, v, mask, dirty);
    let per = work.len().div_ceil(threads.min(work.len()));
    std::thread::scope(|s| {
        for chunk in work.chunks_mut(per) {
            s.spawn(move || {
                for (job, kc, vc, mc, dc) in chunk.iter_mut() {
                    gather_one_sparse(pool, job, geom, kc, vc, mc, dc);
                }
            });
        }
    });
}

/// Dense gather over many slots; same contract as [`gather_sparse_into`]
/// but staging the full cache per slot (`seq_len` is `[b]`).
#[allow(clippy::too_many_arguments)]
pub fn gather_dense_into(pool: &PagedKvPool, jobs: &[GatherJob],
                         geom: &DenseGeom, k: &mut [f32], v: &mut [f32],
                         seq_len: &mut [i32], dirty: &mut [usize],
                         threads: usize) {
    let row_kv = geom.hkv * geom.max_seq * geom.dh;
    if threads <= 1 || jobs.len() <= 1 {
        for job in jobs {
            let r = job.row;
            gather_one_dense(pool, job, geom,
                             &mut k[r * row_kv..(r + 1) * row_kv],
                             &mut v[r * row_kv..(r + 1) * row_kv],
                             &mut seq_len[r..r + 1],
                             &mut dirty[r * geom.hkv..(r + 1) * geom.hkv]);
        }
        return;
    }
    let mut work = build_work!(jobs, row_kv, 1, geom.hkv, k, v, seq_len, dirty);
    let per = work.len().div_ceil(threads.min(work.len()));
    std::thread::scope(|s| {
        for chunk in work.chunks_mut(per) {
            s.spawn(move || {
                for (job, kc, vc, sc, dc) in chunk.iter_mut() {
                    gather_one_dense(pool, job, geom, kc, vc, sc, dc);
                }
            });
        }
    });
}
