//! Deterministic host-only decode engine for the serving test harness.
//!
//! [`SimEngine`] mirrors the PJRT engine's continuous-batching control
//! flow exactly — bounded batch slots, admit+prefill when slots free up,
//! one decode token per step for every running slot, stop on EOS /
//! max-new / context-full, step-boundary control stops (cancellation,
//! deadlines) via the shared [`StopReason::control`] rule, completion
//! reaping, metrics recording, and the [`EngineEvent`] stream — but
//! replaces the device model with a pure token function: every generated
//! token is a deterministic mix of the engine seed and the request's
//! prompt. The output for a request therefore depends **only** on the
//! request content and the engine configuration, never on batch
//! placement, admission order, or shard assignment — which is precisely
//! the property that makes 1-shard vs N-shard completion parity provable
//! in `rust/tests/serving.rs`. (The real engine has the same property
//! under greedy sampling; see `rust/tests/engine.rs`.)
//!
//! KV-page accounting is simulated too: each admitted slot takes
//! [`SimConfig::pages_per_slot`] pages from a pool gauge and returns
//! them when the slot is reaped — for any stop reason, including
//! [`StopReason::Cancelled`] — so the serving tests can assert that
//! cancelling a mid-decode request releases its pages, through the exact
//! code path the real engine uses (stop flag at the step boundary, pages
//! freed in the reap that follows). The gauge is an `Arc<AtomicUsize>`
//! so a test can watch it from outside the shard thread
//! ([`SimEngine::with_pool_gauge`]).

use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::metrics::Metrics;
use super::request::{Completion, EngineEvent, Request, SeqStats, StopReason};
use super::DecodeEngine;
use crate::workload::Vocab;

/// Domain-separation tag folded into every slot's initial state, so a
/// seed of 0 still produces a non-trivial token stream.
const SIM_TAG: u64 = 0x5EE7_A77E_0DEC_0DE5;

/// SplitMix64 finalizer — the per-token mixing function.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Per-token state advance: SplitMix64 plus a fingerprint of the
/// runtime-dispatched SIMD kernel layer. The 13-tap dot product runs
/// through [`crate::util::simd::dot`] — the same kernel the real
/// engine's gate scoring uses — and its result bits fold into the token
/// state, so every served token *depends on kernel output*. That is
/// what lets the serving tests assert the end-to-end acceptance
/// property "auto-dispatch and `--no-simd` produce identical tokens":
/// any bitwise divergence between the SIMD and scalar kernels changes
/// the token stream here. The odd tap count exercises the kernels'
/// tail path on every token; taps are exact small binary fractions so
/// the only rounding is inside the kernel's own reduction.
fn gate_mix(mut z: u64) -> u64 {
    const TAPS: usize = 13;
    let mut a = [0f32; TAPS];
    let mut b = [0f32; TAPS];
    for i in 0..TAPS {
        z = mix(z);
        a[i] = ((z & 0xffff) as i64 - 0x8000) as f32 / 256.0;
        b[i] = (((z >> 16) & 0xffff) as i64 - 0x8000) as f32 / 256.0;
    }
    mix(z ^ crate::util::simd::dot(&a, &b).to_bits() as u64)
}

#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Concurrent batch slots.
    pub batch: usize,
    /// Context window (tokens); mirrors the engine's ContextFull stop.
    pub max_seq: usize,
    /// Engine seed; part of every slot's token-function state.
    pub seed: u64,
    /// Minimum generated tokens before EOS may fire.
    pub min_gen: usize,
    /// EOS fires when `state % eos_every == 0` (0 disables EOS).
    pub eos_every: u64,
    /// Test-harness knob: sleep this long per `step` (0 = off), so
    /// requests stay in flight long enough for timing-dependent serving
    /// behaviour (idle timeouts, admission backpressure, work stealing,
    /// mid-decode cancellation) to be observable deterministically. Not
    /// part of the token function — output parity is unaffected.
    pub step_delay_ms: u64,
    /// Simulated KV pages an active slot holds (pool capacity =
    /// `batch * pages_per_slot`); purely an accounting mirror of the
    /// real engine's paged pool, with no effect on generation.
    pub pages_per_slot: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { batch: 4, max_seq: 512, seed: 0, min_gen: 4, eos_every: 23,
                    step_delay_ms: 0, pages_per_slot: 4 }
    }
}

struct SimSlot {
    req: Request,
    admitted: Instant,
    first_token: Option<Instant>,
    /// Rolling token-function state (seed + prompt hash + emitted tokens).
    state: u64,
    /// Tokens whose KV would be cached: prompt + generated minus the
    /// just-emitted token (exactly the engine's `Slot::len` semantics,
    /// so ContextFull fires on the same step).
    len: usize,
    generated: Vec<i32>,
    stop: Option<StopReason>,
}

pub struct SimEngine {
    pub cfg: SimConfig,
    slots: Vec<Option<SimSlot>>,
    queue: VecDeque<(Request, Instant)>,
    pub metrics: Metrics,
    pub vocab: Vocab,
    /// Ids flagged for cancellation, applied at the next step boundary.
    cancels: HashSet<u64>,
    /// Completions synthesized off-slot (cancelled or deadline-expired
    /// while still queued), drained by the next reap.
    done_early: Vec<Completion>,
    /// Free simulated KV pages (see [`SimConfig::pages_per_slot`]).
    pool_free: Arc<AtomicUsize>,
}

impl SimEngine {
    pub fn new(cfg: SimConfig) -> SimEngine {
        Self::with_pool_gauge(cfg, Arc::new(AtomicUsize::new(0)))
    }

    /// Like [`new`](Self::new), but publishing the free-page count
    /// through a caller-owned gauge, so tests can observe page
    /// allocate/release from outside the shard thread. The gauge is
    /// (re)set to the pool capacity here.
    pub fn with_pool_gauge(cfg: SimConfig,
                           gauge: Arc<AtomicUsize>) -> SimEngine {
        gauge.store(cfg.batch * cfg.pages_per_slot, Ordering::SeqCst);
        SimEngine {
            slots: (0..cfg.batch).map(|_| None).collect(),
            queue: VecDeque::new(),
            metrics: Metrics::new(),
            vocab: Vocab::default(),
            cancels: HashSet::new(),
            done_early: Vec::new(),
            pool_free: gauge,
            cfg,
        }
    }

    /// Free pages in the simulated KV pool (leak detection in tests).
    pub fn pool_free(&self) -> usize {
        self.pool_free.load(Ordering::SeqCst)
    }

    pub fn pool_capacity(&self) -> usize {
        self.cfg.batch * self.cfg.pages_per_slot
    }

    /// The deterministic generation a request would produce, computed
    /// directly (tests compare engine output against this).
    pub fn expected_generation(cfg: &SimConfig, prompt: &[i32],
                               max_new: usize) -> (Vec<i32>, StopReason) {
        let vocab = Vocab::default();
        let mut state = cfg.seed ^ SIM_TAG;
        for &t in prompt {
            state = mix(state ^ t as u64);
        }
        let mut generated = Vec::new();
        let mut len = prompt.len();
        loop {
            if !generated.is_empty() {
                // The previous token enters the cache before the next
                // decode step (engine decode semantics).
                len += 1;
            }
            state = gate_mix(state);
            let tok = Self::token_from(cfg, &vocab, state, generated.len());
            generated.push(tok);
            if let Some(stop) = StopReason::decide(tok, vocab.eos, generated.len(),
                                                   max_new, len, cfg.max_seq) {
                return (generated, stop);
            }
        }
    }

    fn token_from(cfg: &SimConfig, vocab: &Vocab, state: u64,
                  n_generated: usize) -> i32 {
        if cfg.eos_every > 0 && n_generated >= cfg.min_gen
            && state % cfg.eos_every == 0
        {
            return vocab.eos;
        }
        // Keep clear of the control-token range (ids 0..8).
        8 + (state % 200) as i32
    }

    /// Step-boundary control stops (shared rule: [`StopReason::control`]):
    /// flag cancelled / deadline-expired active slots for the reap that
    /// follows, and complete cancelled or expired requests still waiting
    /// in the queue (shared code: [`super::request::expire_queued`])
    /// without ever occupying a slot.
    fn apply_control_stops(&mut self) {
        let now = Instant::now();
        for slot in self.slots.iter_mut().flatten() {
            if slot.stop.is_none() {
                let cancelled = self.cancels.remove(&slot.req.id);
                if let Some(stop) =
                    StopReason::control(cancelled, slot.req.deadline, now)
                {
                    slot.stop = Some(stop);
                }
            }
        }
        super::request::expire_queued(&mut self.queue, &mut self.cancels,
                                      &mut self.done_early, now);
    }

    fn admit_and_prefill(&mut self, sink: &mut dyn FnMut(EngineEvent)) {
        let t0 = Instant::now();
        let cfg = self.cfg;
        let vocab = self.vocab;
        let mut admitted_any = false;
        for entry in self.slots.iter_mut() {
            if entry.is_none() {
                if let Some((req, admitted)) = self.queue.pop_front() {
                    self.pool_free.fetch_sub(cfg.pages_per_slot,
                                             Ordering::SeqCst);
                    // "Prefill": fold the prompt into the token-function
                    // state and emit the first token.
                    let mut state = cfg.seed ^ SIM_TAG;
                    for &t in &req.prompt {
                        state = mix(state ^ t as u64);
                    }
                    sink(EngineEvent::Started { id: req.id });
                    let mut slot = SimSlot {
                        state,
                        len: req.prompt.len(),
                        generated: Vec::new(),
                        stop: None,
                        first_token: None,
                        admitted,
                        req,
                    };
                    Self::emit(&cfg, &vocab, &mut slot, sink);
                    slot.first_token = Some(Instant::now());
                    *entry = Some(slot);
                    admitted_any = true;
                }
            }
        }
        if admitted_any {
            self.metrics.prefill_s.push(t0.elapsed().as_secs_f64());
        }
    }

    /// Generate one token. `slot.len` is NOT advanced here — the caller
    /// accounts cache growth (decode caches the previous token first),
    /// mirroring the engine's prefill/decode split.
    fn emit(cfg: &SimConfig, vocab: &Vocab, slot: &mut SimSlot,
            sink: &mut dyn FnMut(EngineEvent)) {
        slot.state = gate_mix(slot.state);
        let tok = Self::token_from(cfg, vocab, slot.state, slot.generated.len());
        slot.generated.push(tok);
        slot.stop = StopReason::decide(tok, vocab.eos, slot.generated.len(),
                                       slot.req.max_new, slot.len, cfg.max_seq);
        sink(EngineEvent::Token {
            id: slot.req.id,
            tok,
            index: slot.generated.len() - 1,
        });
    }

    fn decode_step(&mut self, sink: &mut dyn FnMut(EngineEvent)) {
        let t0 = Instant::now();
        let cfg = self.cfg;
        let vocab = self.vocab;
        for slot in self.slots.iter_mut().flatten() {
            // The previous step's token enters the cache, then the next
            // token is generated (engine decode order).
            slot.len += 1;
            Self::emit(&cfg, &vocab, slot, sink);
        }
        self.metrics.decode_step_s.push(t0.elapsed().as_secs_f64());
    }

    fn reap_into(&mut self, sink: &mut dyn FnMut(EngineEvent)) {
        for c in self.done_early.drain(..) {
            self.metrics.record_completion(c.ttft, c.e2e, c.generated.len(),
                                           c.stop);
            sink(EngineEvent::Finished(c));
        }
        for entry in self.slots.iter_mut() {
            let finished = entry
                .as_ref()
                .map(|s| s.stop.is_some())
                .unwrap_or(false);
            if finished {
                let slot = entry.take().unwrap();
                self.pool_free.fetch_add(self.cfg.pages_per_slot,
                                         Ordering::SeqCst);
                let now = Instant::now();
                let ttft = slot
                    .first_token
                    .map(|t| t - slot.admitted)
                    .unwrap_or_default();
                let e2e = now - slot.admitted;
                let stop = slot.stop.unwrap();
                self.metrics.record_completion(ttft, e2e, slot.generated.len(),
                                               stop);
                sink(EngineEvent::Finished(Completion {
                    id: slot.req.id,
                    prompt_len: slot.req.prompt.len(),
                    generated: slot.generated,
                    stop,
                    ttft,
                    e2e,
                    stats: SeqStats::default(),
                }));
            }
        }
    }

    /// One engine iteration over the event sink — the single
    /// implementation both trait entry points (`step`, `step_events`)
    /// share, and a control-flow mirror of the PJRT engine's
    /// `step_core`: control stops, an immediate reap (so a cancelled /
    /// expired slot frees its pages *this* step), then admit-or-decode,
    /// then the regular reap.
    fn step_core(&mut self, sink: &mut dyn FnMut(EngineEvent)) -> Result<()> {
        if self.cfg.step_delay_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(
                self.cfg.step_delay_ms));
        }
        self.apply_control_stops();
        self.reap_into(sink);
        if !self.queue.is_empty() && self.slots.iter().any(|s| s.is_none()) {
            self.admit_and_prefill(sink);
        } else if DecodeEngine::active(self) > 0 {
            self.decode_step(sink);
        }
        self.reap_into(sink);
        Ok(())
    }

    /// Run everything currently queued to completion.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !DecodeEngine::idle(self) {
            out.extend(DecodeEngine::step(self)?);
        }
        Ok(out)
    }
}

impl DecodeEngine for SimEngine {
    fn submit_at(&mut self, req: Request, arrived: Instant) {
        assert!(req.prompt.len() + 2 < self.cfg.max_seq,
                "prompt {} too long for context {}", req.prompt.len(),
                self.cfg.max_seq);
        self.metrics.start_clock();
        self.queue.push_back((req, arrived));
    }

    fn step(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        self.step_core(&mut |ev| {
            if let EngineEvent::Finished(c) = ev {
                out.push(c);
            }
        })?;
        Ok(out)
    }

    fn step_events(&mut self, sink: &mut dyn FnMut(EngineEvent)) -> Result<()> {
        self.step_core(sink)
    }

    fn cancel(&mut self, id: u64) -> bool {
        let known = self
            .slots
            .iter()
            .flatten()
            .any(|s| s.stop.is_none() && s.req.id == id)
            || self.queue.iter().any(|(r, _)| r.id == id);
        if known {
            self.cancels.insert(id);
        }
        known
    }

    fn pending(&self) -> usize {
        self.queue.len()
    }

    fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn batch_size(&self) -> usize {
        self.cfg.batch
    }

    fn max_prompt_len(&self) -> usize {
        // submit asserts prompt.len() + 2 < max_seq.
        self.cfg.max_seq.saturating_sub(3)
    }

    fn idle(&self) -> bool {
        // Off-slot completions still owed count as work: a step must run
        // to emit them.
        self.queue.is_empty() && DecodeEngine::active(self) == 0
            && self.done_early.is_empty()
    }

    fn take_metrics(&mut self) -> Metrics {
        std::mem::take(&mut self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
        Request::new(id, prompt, max_new)
    }

    #[test]
    fn generation_is_pure_function_of_prompt_and_seed() {
        let cfg = SimConfig::default();
        let p = vec![1, 42, 99, 7];
        let (a, sa) = SimEngine::expected_generation(&cfg, &p, 16);
        let (b, sb) = SimEngine::expected_generation(&cfg, &p, 16);
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        let other = SimConfig { seed: 1, ..cfg };
        let (c, _) = SimEngine::expected_generation(&other, &p, 16);
        assert_ne!(a, c, "seed must matter");
    }

    #[test]
    fn engine_matches_expected_generation_regardless_of_batching() {
        let cfg = SimConfig { batch: 2, ..Default::default() };
        let prompts: Vec<Vec<i32>> =
            (0..5).map(|i| vec![1, 10 + i, 20 + i, 3]).collect();
        let mut eng = SimEngine::new(cfg);
        for (i, p) in prompts.iter().enumerate() {
            DecodeEngine::submit(&mut eng, req(i as u64, p.clone(), 24));
        }
        let comps = eng.run_to_completion().unwrap();
        assert_eq!(comps.len(), 5);
        for c in comps {
            let (want, stop) =
                SimEngine::expected_generation(&cfg, &prompts[c.id as usize], 24);
            assert_eq!(c.generated, want, "id {}", c.id);
            assert_eq!(c.stop, stop);
        }
        assert_eq!(eng.metrics.requests_completed, 5);
        assert!(eng.metrics.tokens_generated > 0);
        assert_eq!(eng.pool_free(), eng.pool_capacity(), "page leak");
    }

    #[test]
    fn stop_reasons_cover_eos_and_max_new() {
        let cfg = SimConfig::default();
        let mut saw_eos = false;
        let mut saw_max = false;
        for i in 0..40 {
            let (g, stop) =
                SimEngine::expected_generation(&cfg, &[i, i + 1, i + 2], 12);
            match stop {
                StopReason::Eos => {
                    saw_eos = true;
                    assert_eq!(*g.last().unwrap(), Vocab::default().eos);
                }
                StopReason::MaxNewTokens => {
                    saw_max = true;
                    assert_eq!(g.len(), 12);
                }
                StopReason::ContextFull => {}
                StopReason::Cancelled | StopReason::DeadlineExceeded => {
                    unreachable!("control stops never come from decide()")
                }
            }
        }
        assert!(saw_eos && saw_max, "eos={saw_eos} max={saw_max}");
    }

    #[test]
    fn step_events_stream_started_tokens_finished_in_order() {
        let cfg = SimConfig::default();
        let prompt = vec![4, 9, 13];
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(7, prompt.clone(), 16));
        let mut events = Vec::new();
        while !DecodeEngine::idle(&eng) {
            eng.step_events(&mut |ev| events.push(ev)).unwrap();
        }
        assert!(matches!(events[0], EngineEvent::Started { id: 7 }),
                "first event must be Started, got {:?}", events[0]);
        let mut toks = Vec::new();
        let mut finished = None;
        for ev in &events[1..] {
            match ev {
                EngineEvent::Token { id, tok, index } => {
                    assert_eq!(*id, 7);
                    assert!(finished.is_none(), "token after Finished");
                    assert_eq!(*index, toks.len(), "token indices contiguous");
                    toks.push(*tok);
                }
                EngineEvent::Finished(c) => {
                    assert!(finished.is_none(), "duplicate Finished");
                    finished = Some(c.clone());
                }
                EngineEvent::Started { .. } => panic!("duplicate Started"),
            }
        }
        let c = finished.expect("no Finished event");
        assert_eq!(c.generated, toks,
                   "completion must equal the concatenated token events");
        let (want, stop) = SimEngine::expected_generation(&cfg, &prompt, 16);
        assert_eq!(toks, want);
        assert_eq!(c.stop, stop);
    }

    #[test]
    fn cancel_active_request_stops_within_one_step_and_frees_pages() {
        let cfg = SimConfig { batch: 1, eos_every: 0, ..Default::default() };
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(1, vec![2, 3, 5], 1000));
        // Admit + a few decode steps.
        for _ in 0..4 {
            DecodeEngine::step(&mut eng).unwrap();
        }
        assert_eq!(eng.pool_free(),
                   eng.pool_capacity() - cfg.pages_per_slot,
                   "active slot must hold pages");
        assert!(DecodeEngine::cancel(&mut eng, 1), "engine owns request 1");
        assert!(!DecodeEngine::cancel(&mut eng, 99), "unknown id refused");
        let comps = DecodeEngine::step(&mut eng).unwrap();
        assert_eq!(comps.len(), 1, "cancel resolves at the next step");
        assert_eq!(comps[0].stop, StopReason::Cancelled);
        assert_eq!(comps[0].generated.len(), 4,
                   "partial generation is returned");
        assert_eq!(eng.pool_free(), eng.pool_capacity(),
                   "cancelled slot must release its pages");
        assert_eq!(eng.metrics.requests_cancelled, 1);
        assert_eq!(eng.metrics.requests_completed, 0,
                   "cancelled requests are not served completions");
        assert!(DecodeEngine::idle(&eng));
    }

    #[test]
    fn cancel_queued_request_completes_empty_without_taking_a_slot() {
        // batch 1: the second request stays in the engine queue.
        let cfg = SimConfig { batch: 1, eos_every: 0, ..Default::default() };
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(1, vec![2, 3], 6));
        DecodeEngine::submit(&mut eng, req(2, vec![4, 5], 6));
        DecodeEngine::step(&mut eng).unwrap(); // admits 1 only
        assert_eq!(DecodeEngine::pending(&eng), 1);
        assert!(DecodeEngine::cancel(&mut eng, 2));
        let comps = DecodeEngine::step(&mut eng).unwrap();
        let c = comps.iter().find(|c| c.id == 2).expect("cancelled done");
        assert_eq!(c.stop, StopReason::Cancelled);
        assert!(c.generated.is_empty(), "never admitted, nothing generated");
        assert_eq!(DecodeEngine::pending(&eng), 0, "removed from queue");
        // Request 1 is untouched.
        let rest = eng.run_to_completion().unwrap();
        let c1 = rest.iter().find(|c| c.id == 1).expect("request 1 done");
        let (want, _) = SimEngine::expected_generation(&cfg, &[2, 3], 6);
        assert_eq!(c1.generated, want);
        assert_eq!(eng.pool_free(), eng.pool_capacity());
    }

    #[test]
    fn deadline_exceeded_stops_mid_decode_with_partial_output() {
        let cfg = SimConfig { batch: 1, eos_every: 0, step_delay_ms: 2,
                              ..Default::default() };
        let mut eng = SimEngine::new(cfg);
        let deadline = Instant::now() + Duration::from_millis(20);
        let r = req(5, vec![1, 2, 3], 100_000).with_deadline(deadline);
        DecodeEngine::submit(&mut eng, r);
        let comps = eng.run_to_completion().unwrap();
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].stop, StopReason::DeadlineExceeded);
        assert!(!comps[0].generated.is_empty(), "ran until the deadline");
        assert!(comps[0].generated.len() < 100_000, "stopped early");
        assert_eq!(eng.metrics.requests_deadline_expired, 1);
        assert_eq!(eng.pool_free(), eng.pool_capacity());
    }

    #[test]
    fn deadline_expired_while_queued_completes_without_admission() {
        let cfg = SimConfig { batch: 1, eos_every: 0, ..Default::default() };
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(1, vec![7, 8], 4));
        // Already expired when submitted; batch 1 keeps it queued.
        let expired = Instant::now() - Duration::from_millis(1);
        DecodeEngine::submit(&mut eng,
                             req(2, vec![9, 10], 4).with_deadline(expired));
        let comps = eng.run_to_completion().unwrap();
        let c = comps.iter().find(|c| c.id == 2).unwrap();
        assert_eq!(c.stop, StopReason::DeadlineExceeded);
        assert!(c.generated.is_empty());
        assert_eq!(comps.iter().filter(|c| c.id == 1).count(), 1);
        assert_eq!(eng.metrics.requests_deadline_expired, 1);
    }
}
