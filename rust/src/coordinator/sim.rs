//! Deterministic host-only decode engine for the serving test harness.
//!
//! [`SimEngine`] mirrors the PJRT engine's continuous-batching control
//! flow exactly — bounded batch slots, admission into free slots plus at
//! most one prefill chunk ([`SimConfig::prefill_chunk`]) *and* a decode
//! token per step for every slot that was already running (admission
//! never suppresses decode), stop on EOS / max-new / context-full,
//! step-boundary control stops (cancellation, deadlines) via the shared
//! [`StopReason::control`] rule, completion reaping, metrics recording,
//! and the [`EngineEvent`] stream — but
//! replaces the device model with a pure token function: every generated
//! token is a deterministic mix of the engine seed and the request's
//! prompt. The output for a request therefore depends **only** on the
//! request content and the engine configuration, never on batch
//! placement, admission order, or shard assignment — which is precisely
//! the property that makes 1-shard vs N-shard completion parity provable
//! in `rust/tests/serving.rs`. (The real engine has the same property
//! under greedy sampling; see `rust/tests/engine.rs`.)
//!
//! KV-page accounting is simulated too: each admitted slot takes pages
//! from a pool gauge ([`SimConfig::pages_per_slot`] flat, or
//! length-projected when [`SimConfig::page_tokens`] is set) and returns
//! them when the slot is reaped — for any stop reason, including
//! [`StopReason::Cancelled`] — so the serving tests can assert that
//! cancelling a mid-decode request releases its pages, through the exact
//! code path the real engine uses (stop flag at the step boundary, pages
//! freed in the reap that follows). The gauge is an `Arc<AtomicUsize>`
//! so a test can watch it from outside the shard thread
//! ([`SimEngine::with_pool_gauge`]).
//!
//! On top of that sits the robustness machinery the oversubscription
//! tests drive:
//!
//! - **Priority preemption.** When the pool runs dry mid-decode (a
//!   fault shrank it, or a higher-priority request is waiting while the
//!   engine is full), the lowest-priority / youngest active slot is
//!   preempted at a step boundary: its pages are freed through the same
//!   reap bookkeeping cancellation uses, and the request is requeued
//!   carrying its partial generation. Re-admission *replays* the token
//!   function over the already-emitted tokens, so the resumed stream is
//!   bit-identical and token events continue at the next index — no
//!   gaps, no repeats. A bounded retry budget
//!   ([`SimConfig::preempt_retries`]) converts thrashing into a
//!   [`StopReason::ResourceExhausted`] terminal.
//! - **Deterministic fault injection.** [`SimConfig::faults`] holds a
//!   [`FaultSchedule`] of (step, [`Fault`]) pairs — pool shrinks, step
//!   stalls, transient admit failures, injected panics (shard crashes),
//!   and wedges (heartbeat stalls) — applied at exact step numbers, so
//!   adversarial end-to-end tests are reproducible from a seed.

use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::memory::PageGeometry;
use super::metrics::Metrics;
use super::request::{Completion, EngineEvent, Priority, QueuedReq, Request,
                     SeqStats, StopReason};
use super::DecodeEngine;
use crate::kvcache::prefix::{chain_hash, PrefixCache, ROOT_HASH};
use crate::workload::Vocab;

/// Domain-separation tag folded into every slot's initial state, so a
/// seed of 0 still produces a non-trivial token stream.
const SIM_TAG: u64 = 0x5EE7_A77E_0DEC_0DE5;

/// SplitMix64 finalizer — the per-token mixing function.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Per-token state advance: SplitMix64 plus a fingerprint of the
/// runtime-dispatched SIMD kernel layer. The 13-tap dot product runs
/// through [`crate::util::simd::dot`] — the same kernel the real
/// engine's gate scoring uses — and its result bits fold into the token
/// state, so every served token *depends on kernel output*. That is
/// what lets the serving tests assert the end-to-end acceptance
/// property "auto-dispatch and `--no-simd` produce identical tokens":
/// any bitwise divergence between the SIMD and scalar kernels changes
/// the token stream here. The odd tap count exercises the kernels'
/// tail path on every token; taps are exact small binary fractions so
/// the only rounding is inside the kernel's own reduction.
fn gate_mix(mut z: u64) -> u64 {
    const TAPS: usize = 13;
    let mut a = [0f32; TAPS];
    let mut b = [0f32; TAPS];
    for i in 0..TAPS {
        z = mix(z);
        a[i] = ((z & 0xffff) as i64 - 0x8000) as f32 / 256.0;
        b[i] = (((z >> 16) & 0xffff) as i64 - 0x8000) as f32 / 256.0;
    }
    mix(z ^ crate::util::simd::dot(&a, &b).to_bits() as u64)
}

/// One injected fault, applied when the engine's step counter reaches
/// the scheduled step (see [`FaultSchedule`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Clamp the page-pool capacity to at most `pages` (a pool shrink:
    /// capacity never grows back). Active slots whose pages no longer
    /// fit are preempted at the same step boundary.
    ShrinkPool { pages: usize },
    /// The engine does no work (no admit, no decode, no reap) for the
    /// next `steps` steps — a device hiccup. Bounded, so liveness is
    /// only delayed, never lost.
    Stall { steps: u64 },
    /// The next `count` admission opportunities fail transiently: the
    /// request stays queued and the step decodes instead.
    FailAdmits { count: u32 },
    /// The engine thread panics at this step — a shard crash. The shard
    /// supervisor catches the unwind via `AliveGuard`, rescues the dead
    /// shard's requests onto live shards, and respawns the thread.
    Panic,
    /// The engine sleeps `ms` milliseconds inside one step without
    /// yielding — a wedge, not a crash: the shard thread stays alive but
    /// its heartbeat stalls, which the router-side watchdog must detect
    /// (circuit-break) and then forgive (heartbeat resumes).
    Wedge { ms: u64 },
}

/// A deterministic schedule of up to 16 `(step, fault)` pairs. `Copy` so
/// [`SimConfig`] stays `Copy` (the fixed array, rather than a `Vec`, is
/// what buys that — 16 slots let crash/wedge faults compose with a full
/// seeded ShrinkPool/Stall/FailAdmits schedule in one run). Steps are
/// the engine's 1-based step counter (first `step()` call is step 1);
/// several faults may share a step.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultSchedule {
    entries: [Option<(u64, Fault)>; 16],
}

impl FaultSchedule {
    /// No faults (the default).
    pub fn none() -> FaultSchedule {
        FaultSchedule::default()
    }

    /// Builder: add `fault` at `step`. Panics when all 16 slots are used.
    pub fn at(mut self, step: u64, fault: Fault) -> FaultSchedule {
        for e in self.entries.iter_mut() {
            if e.is_none() {
                *e = Some((step, fault));
                return self;
            }
        }
        panic!("fault schedule full (max 16 entries)");
    }

    /// A reproducible adversarial schedule derived from `seed`: one pool
    /// shrink (to between half and three-quarters of `pool_pages`, so a
    /// single average sequence still fits), one short stall, and a burst
    /// of transient admit failures, each at a seed-chosen early step.
    pub fn seeded(seed: u64, pool_pages: usize) -> FaultSchedule {
        let mut rng = crate::util::rng::Rng::new(seed ^ 0xFA_17_5C_ED);
        let floor = (pool_pages / 2).max(1);
        let hi = (pool_pages.saturating_mul(3) / 4).max(floor + 1);
        FaultSchedule::none()
            .at(rng.range(4, 40) as u64,
                Fault::ShrinkPool { pages: rng.range(floor, hi) })
            .at(rng.range(2, 30) as u64,
                Fault::Stall { steps: rng.range(1, 4) as u64 })
            .at(rng.range(2, 30) as u64,
                Fault::FailAdmits { count: rng.range(1, 3) as u32 })
    }

    pub fn is_empty(&self) -> bool {
        self.entries.iter().all(|e| e.is_none())
    }

    /// Faults scheduled for `step`.
    pub fn due(&self, step: u64) -> impl Iterator<Item = Fault> + '_ {
        self.entries
            .iter()
            .flatten()
            .filter(move |(s, _)| *s == step)
            .map(|(_, f)| *f)
    }
}

#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Concurrent batch slots.
    pub batch: usize,
    /// Context window (tokens); mirrors the engine's ContextFull stop.
    pub max_seq: usize,
    /// Engine seed; part of every slot's token-function state.
    pub seed: u64,
    /// Minimum generated tokens before EOS may fire.
    pub min_gen: usize,
    /// EOS fires when `state % eos_every == 0` (0 disables EOS).
    pub eos_every: u64,
    /// Test-harness knob: sleep this long per `step` (0 = off), so
    /// requests stay in flight long enough for timing-dependent serving
    /// behaviour (idle timeouts, admission backpressure, work stealing,
    /// mid-decode cancellation) to be observable deterministically. Not
    /// part of the token function — output parity is unaffected.
    pub step_delay_ms: u64,
    /// Simulated KV pages an active slot holds (pool capacity =
    /// `batch * pages_per_slot`); purely an accounting mirror of the
    /// real engine's paged pool, with no effect on generation.
    pub pages_per_slot: usize,
    /// When non-zero, switch page accounting from the flat
    /// `pages_per_slot`-per-sequence model to a length-projected one: an
    /// admitted sequence holds `ceil((prompt + max_new + 1) /
    /// page_tokens)` pages for its whole slot lifetime (its projected
    /// peak — the conservative shape the admission planner budgets
    /// with). Pool capacity stays `batch * pages_per_slot`. 0 (the
    /// default) preserves the legacy flat model exactly.
    pub page_tokens: usize,
    /// How many times a request may be preempted-and-requeued before it
    /// is terminated with [`StopReason::ResourceExhausted`].
    pub preempt_retries: u32,
    /// Deterministic fault injection schedule (default: none).
    pub faults: FaultSchedule,
    /// Chunked prefill, mirroring [`EngineConfig::prefill_chunk`]: the
    /// per-step budget of prefill tokens shared by every half-prefilled
    /// slot (0 = monolithic). Generation content is a pure function of
    /// (seed, prompt) and thus unaffected; what chunking changes is
    /// *step accounting* — admitting an `n`-token prompt takes
    /// `ceil(n / chunk)` steps during which the slot holds pages, emits
    /// nothing, and can be cancelled / expired / preempted, while the
    /// already-running batch keeps decoding every step. The default
    /// matches the engine's (128).
    ///
    /// [`EngineConfig::prefill_chunk`]: super::engine::EngineConfig::prefill_chunk
    pub prefill_chunk: usize,
    /// Content-addressed prefix caching (default off, preserving every
    /// pre-existing trace bit-for-bit). Requires the token paging model
    /// (`page_tokens > 0` — the cache's block IS the page unit: one
    /// cached block ⇔ one simulated page); silently inert under the flat
    /// model, which has no per-block pages to share. When on, admission
    /// looks up the longest cached block-aligned prefix of the prompt,
    /// restores the token-function fold state checkpointed at that
    /// block boundary, and starts chunked prefill at the first uncached
    /// block — generation stays a pure function of (seed, prompt), so
    /// warm streams are bit-identical to cold ones while
    /// `prefill_tokens` records the skipped work.
    pub prefix_cache: bool,
    /// Prefix-cache capacity in blocks (0 = unbounded); LRU leaves are
    /// evicted beyond it.
    pub prefix_cache_blocks: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { batch: 4, max_seq: 512, seed: 0, min_gen: 4, eos_every: 23,
                    step_delay_ms: 0, pages_per_slot: 4, page_tokens: 0,
                    preempt_retries: 3, faults: FaultSchedule::none(),
                    prefill_chunk: 128, prefix_cache: false,
                    prefix_cache_blocks: 0 }
    }
}

struct SimSlot {
    req: Request,
    admitted: Instant,
    first_token: Option<Instant>,
    /// Rolling token-function state (seed + prompt hash + emitted tokens).
    state: u64,
    /// Tokens whose KV would be cached: prompt + generated minus the
    /// just-emitted token (exactly the engine's `Slot::len` semantics,
    /// so ContextFull fires on the same step).
    len: usize,
    generated: Vec<i32>,
    stop: Option<StopReason>,
    /// Pages this slot holds (returned to the pool on reap or preempt).
    pages: usize,
    /// Times this request has been preempted before this admission.
    retries: u32,
    /// Prefill progress: effective-span tokens folded so far. While
    /// `< prefill_target` the slot is half-prefilled — it holds pages
    /// but emits nothing and does not decode (the engine's
    /// `Slot::prefilling` mirror).
    prefill_pos: usize,
    /// Effective prefill span: the prompt for fresh requests,
    /// `prompt + resume - 1` tokens for preempted ones (same count the
    /// engine stages, so chunked admission takes the same number of
    /// steps on both engines).
    prefill_target: usize,
    /// Resume tokens awaiting the quiet replay that runs when prefill
    /// completes. Until then this is also the stream the client has
    /// already seen, which reap/preempt must carry instead of the empty
    /// `generated`.
    pending_resume: Vec<i32>,
    /// Chain hash of the deepest prefix block this slot has pinned
    /// (reused at admission or published/pinned while folding);
    /// [`ROOT_HASH`] while none. The slot's pins always form a
    /// contiguous chain from the root — `prefix_blocks` long — which is
    /// what makes unpin-on-any-release exact.
    prefix_hash: u64,
    /// Length of the pinned chain.
    prefix_blocks: usize,
}

impl SimSlot {
    fn prefilling(&self) -> bool {
        self.prefill_pos < self.prefill_target
    }
}

pub struct SimEngine {
    pub cfg: SimConfig,
    slots: Vec<Option<SimSlot>>,
    queue: VecDeque<QueuedReq>,
    pub metrics: Metrics,
    pub vocab: Vocab,
    /// Ids flagged for cancellation, applied at the next step boundary.
    cancels: HashSet<u64>,
    /// Completions synthesized off-slot (cancelled or deadline-expired
    /// while still queued, or resource-exhausted), drained by the next
    /// reap.
    done_early: Vec<Completion>,
    /// Free simulated KV pages, published as
    /// `capacity_pages - held_pages` (see [`SimConfig::pages_per_slot`]).
    pool_free: Arc<AtomicUsize>,
    /// Current pool capacity; starts at `batch * pages_per_slot`, only
    /// ever shrunk by [`Fault::ShrinkPool`].
    capacity_pages: usize,
    /// Pages held by active slots.
    held_pages: usize,
    /// 1-based step counter driving the fault schedule.
    step_no: u64,
    /// Remaining [`Fault::Stall`] steps.
    stall_left: u64,
    /// Remaining [`Fault::FailAdmits`] admission failures.
    fail_admits_left: u32,
    /// Content-addressed prefix index; payload = the token-function fold
    /// state checkpointed at the block's boundary. Each resident block
    /// owns one simulated page (`cache_pages`), transferred from the
    /// publishing slot and returned to the pool only on eviction.
    prefix: PrefixCache<u64>,
    /// Pages owned by the prefix cache (`held_pages` counts live slots
    /// only; free = capacity − held − cached).
    cache_pages: usize,
}

impl SimEngine {
    pub fn new(cfg: SimConfig) -> SimEngine {
        Self::with_pool_gauge(cfg, Arc::new(AtomicUsize::new(0)))
    }

    /// Like [`new`](Self::new), but publishing the free-page count
    /// through a caller-owned gauge, so tests can observe page
    /// allocate/release from outside the shard thread. The gauge is
    /// (re)set to the pool capacity here.
    pub fn with_pool_gauge(cfg: SimConfig,
                           gauge: Arc<AtomicUsize>) -> SimEngine {
        let capacity = cfg.batch * cfg.pages_per_slot;
        gauge.store(capacity, Ordering::SeqCst);
        SimEngine {
            slots: (0..cfg.batch).map(|_| None).collect(),
            queue: VecDeque::new(),
            metrics: Metrics::new(),
            vocab: Vocab::default(),
            cancels: HashSet::new(),
            done_early: Vec::new(),
            pool_free: gauge,
            capacity_pages: capacity,
            held_pages: 0,
            step_no: 0,
            stall_left: 0,
            fail_admits_left: 0,
            prefix: PrefixCache::new(cfg.page_tokens.max(1),
                                     cfg.prefix_cache_blocks),
            cache_pages: 0,
            cfg,
        }
    }

    /// Prefix caching active? (Needs the token paging model — the flat
    /// model has no per-block pages to share.)
    fn prefix_on(&self) -> bool {
        self.cfg.prefix_cache && self.cfg.page_tokens > 0
    }

    /// Blocks of `prompt` an admission could splice from the cache:
    /// the longest cached chain, capped so at least one token is always
    /// left to fold (a fully cached block-aligned prompt still takes one
    /// chunk step to sample its first token — TTFT and the step
    /// machinery stay uniform).
    fn probe_reuse(&self, prompt: &[i32], resume_len: usize) -> usize {
        if !self.prefix_on() {
            return 0;
        }
        let target = prompt.len() + resume_len.saturating_sub(1);
        let mut r = self.prefix.probe(prompt).blocks;
        while r > 0 && r * self.cfg.page_tokens >= target {
            r -= 1;
        }
        r
    }

    /// Blocks resident in the prefix cache (each owns one pool page).
    pub fn prefix_cached_blocks(&self) -> usize {
        self.cache_pages
    }

    /// Drop every unpinned cached block, returning its pages to the
    /// pool; returns the count evicted (tests use this to prove the
    /// gauge returns to baseline — cache pages are a cache, not a leak).
    pub fn prefix_evict_all(&mut self) -> usize {
        let mut freed = Vec::new();
        let n = self.prefix.evict_all(&mut freed);
        self.cache_pages -= n;
        self.metrics.prefix_evictions += n as u64;
        self.publish_gauge();
        n
    }

    /// Free pages in the simulated KV pool (leak detection in tests).
    pub fn pool_free(&self) -> usize {
        self.pool_free.load(Ordering::SeqCst)
    }

    /// Current pool capacity (shrinks under [`Fault::ShrinkPool`]).
    pub fn pool_capacity(&self) -> usize {
        self.capacity_pages
    }

    fn publish_gauge(&self) {
        self.pool_free.store(
            self.capacity_pages
                .saturating_sub(self.held_pages + self.cache_pages),
            Ordering::SeqCst);
    }

    /// Pages a sequence holds for its slot lifetime (projected peak).
    fn seq_pages(cfg: &SimConfig, prompt_len: usize, max_new: usize) -> usize {
        if cfg.page_tokens == 0 {
            cfg.pages_per_slot
        } else {
            (prompt_len + max_new + 1).div_ceil(cfg.page_tokens)
        }
    }

    /// The deterministic generation a request would produce, computed
    /// directly (tests compare engine output against this).
    pub fn expected_generation(cfg: &SimConfig, prompt: &[i32],
                               max_new: usize) -> (Vec<i32>, StopReason) {
        let vocab = Vocab::default();
        let mut state = cfg.seed ^ SIM_TAG;
        for &t in prompt {
            state = mix(state ^ t as u64);
        }
        let mut generated = Vec::new();
        let mut len = prompt.len();
        loop {
            if !generated.is_empty() {
                // The previous token enters the cache before the next
                // decode step (engine decode semantics).
                len += 1;
            }
            state = gate_mix(state);
            let tok = Self::token_from(cfg, &vocab, state, generated.len());
            generated.push(tok);
            if let Some(stop) = StopReason::decide(tok, vocab.eos, generated.len(),
                                                   max_new, len, cfg.max_seq) {
                return (generated, stop);
            }
        }
    }

    fn token_from(cfg: &SimConfig, vocab: &Vocab, state: u64,
                  n_generated: usize) -> i32 {
        if cfg.eos_every > 0 && n_generated >= cfg.min_gen
            && state % cfg.eos_every == 0
        {
            return vocab.eos;
        }
        // Keep clear of the control-token range (ids 0..8).
        8 + (state % 200) as i32
    }

    /// Apply faults scheduled for the current step.
    fn apply_faults(&mut self) {
        let faults = self.cfg.faults;
        for f in faults.due(self.step_no) {
            match f {
                Fault::ShrinkPool { pages } => {
                    self.capacity_pages = self.capacity_pages.min(pages);
                    self.publish_gauge();
                }
                Fault::Stall { steps } => self.stall_left += steps,
                Fault::FailAdmits { count } => self.fail_admits_left += count,
                // A real crash: the unwind rips through shard_main, the
                // AliveGuard flips the shard dead, and the supervisor
                // takes over. Nothing here is cleaned up on purpose —
                // that is exactly the mess rescue must reconcile.
                Fault::Panic => {
                    panic!("injected panic fault at step {}", self.step_no)
                }
                // A wedge: the thread blocks mid-step without yielding,
                // so the shard's heartbeat goes quiet while `alive`
                // stays true — the watchdog case, not the crash case.
                Fault::Wedge { ms } => {
                    std::thread::sleep(std::time::Duration::from_millis(ms));
                }
            }
        }
    }

    /// Step-boundary control stops (shared rule: [`StopReason::control`]):
    /// flag cancelled / deadline-expired active slots for the reap that
    /// follows, and complete cancelled or expired requests still waiting
    /// in the queue (shared code: [`super::request::expire_queued`])
    /// without ever occupying a slot.
    fn apply_control_stops(&mut self) {
        let now = Instant::now();
        for slot in self.slots.iter_mut().flatten() {
            if slot.stop.is_none() {
                let cancelled = self.cancels.remove(&slot.req.id);
                if let Some(stop) =
                    StopReason::control(cancelled, slot.req.deadline, now)
                {
                    slot.stop = Some(stop);
                }
            }
        }
        super::request::expire_queued(&mut self.queue, &mut self.cancels,
                                      &mut self.done_early, now);
    }

    /// Index of the queued request admission should take next: highest
    /// priority, front-most among equals. Strict head-of-line within a
    /// priority class — admission never skips ahead to a smaller
    /// lower-priority request.
    fn best_queued(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, q) in self.queue.iter().enumerate() {
            match best {
                None => best = Some(i),
                Some(b) => {
                    if q.req.priority > self.queue[b].req.priority {
                        best = Some(i);
                    }
                }
            }
        }
        best
    }

    /// Can the next admission candidate actually be admitted right now
    /// (free slot + pages fit)? Pages the prefix cache could either
    /// contribute (reused blocks need no new page) or yield (unpinned
    /// cached blocks are evictable on demand) count as available —
    /// mirrored exactly by `admit_slots`, so readiness and admission
    /// never disagree.
    fn admit_ready(&self) -> bool {
        match self.best_queued() {
            None => false,
            Some(qi) => {
                let q = &self.queue[qi];
                let need = Self::seq_pages(&self.cfg, q.req.prompt.len(),
                                           q.req.max_new);
                let reused = self.probe_reuse(&q.req.prompt, q.resume.len());
                // Exact pool math: unpinned cached blocks are evictable
                // (count as free) — except the candidate's own reuse
                // chain, which must stay resident to be reused. This is
                // the same arithmetic `admit_slots` realises by pinning
                // the chain first and then evicting, so readiness and
                // admission never disagree.
                let mut keep = self.cache_pages
                    - self.prefix.evictable().min(self.cache_pages);
                if reused > 0 {
                    let hit = self.prefix.probe(&q.req.prompt);
                    let h = self.prefix.ancestor(hit.hash, hit.blocks - reused);
                    keep += self.prefix.chain_unpinned(h, reused);
                }
                self.slots.iter().any(|s| s.is_none())
                    && self.held_pages + keep + (need - reused)
                        <= self.capacity_pages
            }
        }
    }

    /// Fill free slots from the queue (pages reserved up front, the
    /// planner's conservative shape). Each new occupant starts
    /// half-prefilled at position 0 — the state folding happens in
    /// [`SimEngine::advance_prefill`], one chunk per step.
    fn admit_slots(&mut self) {
        let cfg = self.cfg;
        while let Some(qi) = self.best_queued() {
            let Some(si) = self.slots.iter().position(|s| s.is_none()) else {
                break;
            };
            let need = Self::seq_pages(&cfg, self.queue[qi].req.prompt.len(),
                                       self.queue[qi].req.max_new);
            let reused = self.probe_reuse(&self.queue[qi].req.prompt,
                                          self.queue[qi].resume.len());
            let private = need - reused;
            // Pin the reuse chain BEFORE evicting for the shortfall —
            // pinned blocks are invisible to eviction, so the chain this
            // admission depends on can't be chosen as a victim while we
            // make room for its private tail.
            let (state, prefix_hash) = if reused > 0 {
                let hit = self.prefix.lookup(&self.queue[qi].req.prompt);
                debug_assert!(hit.blocks >= reused);
                let h = self.prefix.ancestor(hit.hash, hit.blocks - reused);
                self.prefix.pin(h, reused);
                (*self.prefix.payload(h).expect("pinned prefix node"), h)
            } else {
                (cfg.seed ^ SIM_TAG, ROOT_HASH)
            };
            // Yield unpinned cached blocks back to the pool before
            // giving up on the admission — the cache must never crowd
            // out live traffic.
            let used = self.held_pages + self.cache_pages;
            if used + private > self.capacity_pages {
                let shortfall = used + private - self.capacity_pages;
                let mut freed = Vec::new();
                let n = self.prefix.evict(shortfall, &mut freed);
                self.cache_pages -= n;
                self.metrics.prefix_evictions += n as u64;
                self.publish_gauge();
            }
            if self.held_pages + self.cache_pages + private > self.capacity_pages {
                if reused > 0 {
                    self.prefix.unpin(prefix_hash, reused);
                }
                break;
            }
            let QueuedReq { req, arrived, resume, first_token_at, retries, .. } =
                self.queue.remove(qi).unwrap();
            if reused > 0 {
                self.metrics.prefix_hits += 1;
                self.metrics.prefix_blocks_reused += reused as u64;
            }
            self.held_pages += private;
            self.publish_gauge();
            self.metrics.pages_peak = self.metrics.pages_peak
                .max(self.held_pages + self.cache_pages);
            let target = req.prompt.len() + resume.len().saturating_sub(1);
            self.slots[si] = Some(SimSlot {
                state,
                len: req.prompt.len(),
                generated: Vec::new(),
                stop: None,
                first_token: first_token_at,
                admitted: arrived,
                pages: private,
                retries,
                prefill_pos: reused * cfg.page_tokens,
                prefill_target: target,
                pending_resume: resume,
                prefix_hash,
                prefix_blocks: reused,
                req,
            });
        }
    }

    /// Advance half-prefilled slots by one shared chunk of
    /// `prefill_chunk` tokens (unbounded when 0), in slot order.
    /// "Prefill" here is folding prompt tokens into the token-function
    /// state — made genuinely resumable so step accounting mirrors the
    /// engine's chunked staging: an `n`-token effective span takes
    /// `ceil(n / chunk)` steps, during which decode keeps running for
    /// the rest of the batch. A slot whose cursor reaches its target
    /// completes admission — fresh requests emit `Started` plus the
    /// first token (TTFT stops here, exactly like the engine sampling
    /// from the final chunk's logits); preempted requests quietly replay
    /// their resume tokens:  the stream is a pure function of
    /// (seed, prompt), so the replay is bit-identical and the slot lands
    /// in the exact state it was preempted from — the next decode emits
    /// the next index, no `Started` / `Token` re-emission, no gaps, no
    /// repeats. Resume positions past the prompt fold nothing but still
    /// consume chunk budget (they are staged tokens on the engine side).
    fn advance_prefill(&mut self, sink: &mut dyn FnMut(EngineEvent)) {
        let t0 = Instant::now();
        let cfg = self.cfg;
        let vocab = self.vocab;
        let mut budget = if cfg.prefill_chunk == 0 {
            usize::MAX
        } else {
            cfg.prefill_chunk
        };
        let mut chunk_tokens = 0u64;
        let prefix_on = self.prefix_on();
        let bs = cfg.page_tokens;
        let SimEngine { slots, prefix, metrics, held_pages, cache_pages, .. } =
            self;
        for slot in slots.iter_mut().flatten() {
            if budget == 0 {
                break; // chunk spent; remaining slots resume next step
            }
            if !slot.prefilling() || slot.stop.is_some() {
                continue;
            }
            let pos = slot.prefill_pos;
            let end = slot.prefill_target.min(pos + budget);
            let plen = slot.req.prompt.len();
            if prefix_on {
                // Fold token by token, checkpointing the state at every
                // full-prompt-block boundary: publishing transfers the
                // block's page from the slot to the cache (first
                // publisher wins; a block someone else published first
                // is pinned instead and the slot keeps its private
                // page). Either way the slot's pins stay a contiguous
                // chain from the root.
                for i in pos.min(plen)..end.min(plen) {
                    slot.state = mix(slot.state ^ slot.req.prompt[i] as u64);
                    let done = i + 1;
                    if done % bs == 0 && done / bs > slot.prefix_blocks {
                        let next = chain_hash(slot.prefix_hash,
                                              &slot.req.prompt[done - bs..done]);
                        let mut freed = Vec::new();
                        if prefix.insert(slot.prefix_hash, next, slot.state,
                                         &mut freed) {
                            debug_assert!(slot.pages >= 2,
                                          "publish would strand the slot");
                            slot.pages -= 1;
                            *held_pages -= 1;
                            *cache_pages += 1;
                        } else {
                            prefix.pin(next, 1);
                        }
                        *cache_pages -= freed.len();
                        metrics.prefix_evictions += freed.len() as u64;
                        slot.prefix_hash = next;
                        slot.prefix_blocks += 1;
                    }
                }
            } else {
                for &t in &slot.req.prompt[pos.min(plen)..end.min(plen)] {
                    slot.state = mix(slot.state ^ t as u64);
                }
            }
            budget -= end - pos;
            chunk_tokens += (end - pos) as u64;
            slot.prefill_pos = end;
            if slot.prefilling() {
                continue; // still half-prefilled; nothing emitted yet
            }
            if slot.pending_resume.is_empty() {
                sink(EngineEvent::Started { id: slot.req.id });
                Self::emit(&cfg, &vocab, slot, sink);
                slot.first_token = Some(Instant::now());
            } else {
                let resume = std::mem::take(&mut slot.pending_resume);
                let mut quiet = |_: EngineEvent| {};
                for j in 0..resume.len() {
                    if j > 0 {
                        slot.len += 1;
                    }
                    Self::emit(&cfg, &vocab, slot, &mut quiet);
                }
                debug_assert_eq!(slot.generated, resume,
                                 "resume replay must be bit-identical");
            }
        }
        if chunk_tokens > 0 {
            self.metrics.prefill_chunks += 1;
            self.metrics.prefill_tokens += chunk_tokens;
            self.metrics.prefill_s.push(t0.elapsed().as_secs_f64());
        }
        if prefix_on {
            // Page ownership may have moved slot→cache (sum-invariant)
            // and cap evictions may have freed pages.
            self.publish_gauge();
        }
    }

    /// Generate one token. `slot.len` is NOT advanced here — the caller
    /// accounts cache growth (decode caches the previous token first),
    /// mirroring the engine's prefill/decode split.
    fn emit(cfg: &SimConfig, vocab: &Vocab, slot: &mut SimSlot,
            sink: &mut dyn FnMut(EngineEvent)) {
        slot.state = gate_mix(slot.state);
        let tok = Self::token_from(cfg, vocab, slot.state, slot.generated.len());
        slot.generated.push(tok);
        slot.stop = StopReason::decide(tok, vocab.eos, slot.generated.len(),
                                       slot.req.max_new, slot.len, cfg.max_seq);
        sink(EngineEvent::Token {
            id: slot.req.id,
            tok,
            index: slot.generated.len() - 1,
        });
    }

    /// One decode token for `active` — the slots that had completed
    /// prefill before this step's chunk ran (`step_core` snapshots the
    /// set, so a slot that sampled its first token this very step waits
    /// for the next one, and a slot preempted after the snapshot is
    /// simply gone and skipped).
    fn decode_step(&mut self, sink: &mut dyn FnMut(EngineEvent),
                   active: &[usize]) {
        let t0 = Instant::now();
        let cfg = self.cfg;
        let vocab = self.vocab;
        for &i in active {
            let Some(slot) = self.slots[i].as_mut() else { continue };
            // The previous step's token enters the cache, then the next
            // token is generated (engine decode order).
            slot.len += 1;
            Self::emit(&cfg, &vocab, slot, sink);
        }
        self.metrics.decode_step_s.push(t0.elapsed().as_secs_f64());
    }

    /// Preempt one active slot: the lowest-priority victim, youngest
    /// (latest-admitted) among equals. With `only_if_below = Some(p)`,
    /// only a victim of priority strictly below `p` is taken — the rule
    /// that makes "a lower-priority request never survives while a
    /// higher-priority one is starved" hold without ever letting
    /// equal-priority requests churn each other. A victim whose retry
    /// budget is spent is terminated with `ResourceExhausted` instead of
    /// requeued; either way its pages return to the pool at this step
    /// boundary. Returns whether a slot was freed.
    fn preempt_one(&mut self, sink: &mut dyn FnMut(EngineEvent),
                   only_if_below: Option<Priority>) -> bool {
        let mut victim: Option<usize> = None;
        for (i, slot) in self.slots.iter().enumerate() {
            let Some(s) = slot else { continue };
            if s.stop.is_some() {
                continue; // already terminating; its pages free this step
            }
            match victim {
                None => victim = Some(i),
                Some(v) => {
                    let cur = self.slots[v].as_ref().unwrap();
                    let weaker = s.req.priority < cur.req.priority
                        || (s.req.priority == cur.req.priority
                            && s.admitted >= cur.admitted);
                    if weaker {
                        victim = Some(i);
                    }
                }
            }
        }
        let Some(vi) = victim else { return false };
        if let Some(floor) = only_if_below {
            if self.slots[vi].as_ref().unwrap().req.priority >= floor {
                return false;
            }
        }
        let slot = self.slots[vi].take().unwrap();
        self.held_pages -= slot.pages;
        if slot.prefix_blocks > 0 {
            // The victim's prefix pins drop; the cached blocks stay warm
            // (its re-admission will reuse them instead of re-prefilling).
            self.prefix.unpin(slot.prefix_hash, slot.prefix_blocks);
        }
        self.publish_gauge();
        // What the client has actually seen: a half-prefilled victim has
        // emitted nothing this admission, so its stream state is the
        // resume tokens it was re-admitted with, not the (empty)
        // `generated` of the unfinished replay.
        let emitted = if slot.prefilling() {
            slot.pending_resume
        } else {
            slot.generated
        };
        if slot.retries >= self.cfg.preempt_retries {
            let now = Instant::now();
            self.done_early.push(Completion {
                id: slot.req.id,
                prompt_len: slot.req.prompt.len(),
                generated: emitted,
                stop: StopReason::ResourceExhausted,
                ttft: slot.first_token
                    .map(|t| t.saturating_duration_since(slot.admitted))
                    .unwrap_or_default(),
                e2e: now.saturating_duration_since(slot.admitted),
                stats: SeqStats::default(),
            });
        } else {
            sink(EngineEvent::Preempted { id: slot.req.id });
            self.metrics.requests_preempted += 1;
            self.queue.push_front(QueuedReq {
                req: slot.req,
                arrived: slot.admitted,
                resume: emitted,
                first_token_at: slot.first_token,
                retries: slot.retries + 1,
                sticky: false,
            });
        }
        true
    }

    /// After a pool shrink: fit `held + cached` back under capacity —
    /// unpinned prefix blocks are yielded first (evicting a cache entry
    /// is free; preempting a live sequence costs a re-prefill), live
    /// slots are preempted only once the cache has nothing left to give.
    fn shed_deficit(&mut self, sink: &mut dyn FnMut(EngineEvent)) {
        while self.held_pages + self.cache_pages > self.capacity_pages {
            if self.cache_pages > 0 {
                let mut freed = Vec::new();
                if self.prefix.evict(1, &mut freed) == 1 {
                    self.cache_pages -= 1;
                    self.metrics.prefix_evictions += 1;
                    self.publish_gauge();
                    continue;
                }
            }
            if !self.preempt_one(sink, None) {
                break;
            }
        }
    }

    /// Terminate queued requests that can never fit the (possibly
    /// shrunken) pool — without this sweep they would starve forever.
    fn expire_infeasible(&mut self) {
        let now = Instant::now();
        let cfg = self.cfg;
        let cap = self.capacity_pages;
        let mut i = 0;
        while i < self.queue.len() {
            let q = &self.queue[i];
            if Self::seq_pages(&cfg, q.req.prompt.len(), q.req.max_new) > cap {
                let q = self.queue.remove(i).unwrap();
                self.cancels.remove(&q.req.id);
                self.done_early.push(Completion {
                    id: q.req.id,
                    prompt_len: q.req.prompt.len(),
                    generated: q.resume,
                    stop: StopReason::ResourceExhausted,
                    ttft: q.first_token_at
                        .map(|t| t.saturating_duration_since(q.arrived))
                        .unwrap_or_default(),
                    e2e: now.saturating_duration_since(q.arrived),
                    stats: SeqStats::default(),
                });
            } else {
                i += 1;
            }
        }
    }

    /// When the best queued request cannot be admitted (engine full, or
    /// pages short), evict one strictly-lower-priority occupant in its
    /// favour. One victim per step keeps preemption at step-boundary
    /// granularity.
    fn pressure_preempt(&mut self, sink: &mut dyn FnMut(EngineEvent)) {
        let Some(qi) = self.best_queued() else { return };
        let q = &self.queue[qi];
        let need = Self::seq_pages(&self.cfg, q.req.prompt.len(), q.req.max_new);
        if need > self.capacity_pages {
            return; // infeasible; expire_infeasible handles it
        }
        let floor = q.req.priority;
        self.preempt_one(sink, Some(floor));
    }

    fn reap_into(&mut self, sink: &mut dyn FnMut(EngineEvent)) {
        for c in self.done_early.drain(..) {
            self.metrics.record_completion(c.ttft, c.e2e, c.generated.len(),
                                           c.stop);
            sink(EngineEvent::Finished(c));
        }
        for i in 0..self.slots.len() {
            let finished = self.slots[i]
                .as_ref()
                .map(|s| s.stop.is_some())
                .unwrap_or(false);
            if finished {
                let slot = self.slots[i].take().unwrap();
                self.held_pages -= slot.pages;
                if slot.prefix_blocks > 0 {
                    // Every terminal path — EOS, max-new, cancel,
                    // deadline, exhaustion — drops the slot's prefix
                    // pins here, so a cancel storm can never leak a
                    // refcount; the blocks themselves stay cached.
                    self.prefix.unpin(slot.prefix_hash, slot.prefix_blocks);
                }
                self.publish_gauge();
                let now = Instant::now();
                let ttft = slot
                    .first_token
                    .map(|t| t - slot.admitted)
                    .unwrap_or_default();
                let e2e = now - slot.admitted;
                let stop = slot.stop.unwrap();
                // A slot cancelled / expired half-prefilled reports the
                // stream the client actually saw (its pending resume
                // tokens; empty for a fresh request) — its pages free
                // through this same path either way.
                let generated = if slot.prefilling() {
                    slot.pending_resume
                } else {
                    slot.generated
                };
                self.metrics.record_completion(ttft, e2e, generated.len(),
                                               stop);
                sink(EngineEvent::Finished(Completion {
                    id: slot.req.id,
                    prompt_len: slot.req.prompt.len(),
                    generated,
                    stop,
                    ttft,
                    e2e,
                    stats: SeqStats::default(),
                }));
            }
        }
    }

    /// One engine iteration over the event sink — the single
    /// implementation both trait entry points (`step`, `step_events`)
    /// share, and a control-flow mirror of the PJRT engine's
    /// `step_core`: faults, control stops, an immediate reap (so a
    /// cancelled / expired slot frees its pages *this* step), deficit
    /// shedding + infeasibility sweep, then at most one prefill chunk
    /// *and* a decode step for the already-running batch, then the
    /// regular reap. Admission never suppresses decode, and — the fault
    /// path and normal path share one step shape — a step that burns a
    /// [`Fault::FailAdmits`] opportunity decodes exactly like a step
    /// that admits (the fault suppresses slot filling only; the chunk
    /// phase and decode run regardless), so chaos replays exercise the
    /// real scheduler instead of a divergent fault-only variant.
    fn step_core(&mut self, sink: &mut dyn FnMut(EngineEvent)) -> Result<()> {
        if self.cfg.step_delay_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(
                self.cfg.step_delay_ms));
        }
        self.step_no += 1;
        self.apply_faults();
        if self.stall_left > 0 {
            self.stall_left -= 1;
            return Ok(());
        }
        self.apply_control_stops();
        self.reap_into(sink);
        self.shed_deficit(sink);
        self.expire_infeasible();
        // Decode-eligible set snapshotted *before* this step's admission
        // and prefill chunk: a slot whose prefill completes this step
        // emitted its first token from the chunk and joins decode next
        // step (the engine's exact rule).
        let decode_set: Vec<usize> = (0..self.cfg.batch)
            .filter(|&i| {
                self.slots[i]
                    .as_ref()
                    .map(|s| !s.prefilling() && s.stop.is_none())
                    .unwrap_or(false)
            })
            .collect();
        if self.admit_ready() {
            if self.fail_admits_left > 0 {
                // Transient admission fault: skip slot filling only.
                self.fail_admits_left -= 1;
            } else {
                self.admit_slots();
            }
        } else {
            self.pressure_preempt(sink);
        }
        self.advance_prefill(sink);
        if !decode_set.is_empty() {
            self.decode_step(sink, &decode_set);
        }
        self.reap_into(sink);
        Ok(())
    }

    /// Run everything currently queued to completion.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !DecodeEngine::idle(self) {
            out.extend(DecodeEngine::step(self)?);
        }
        Ok(out)
    }
}

impl DecodeEngine for SimEngine {
    fn submit_at(&mut self, req: Request, arrived: Instant) {
        self.submit_queued(QueuedReq::fresh(req, arrived));
    }

    fn submit_queued(&mut self, q: QueuedReq) {
        // Guard on the *effective* prefill span, not the prompt alone
        // (the engine's exact rule): re-admission replays
        // `prompt ++ resume[..k-1]`, so a request preempted near the
        // context limit carries resume tokens that count against the
        // span. A legitimately preempted request always satisfies this
        // (it was alive, so its cached length was < max_seq - 2); the
        // assert catches corrupted or hand-built resume state before it
        // can overrun the staged span at re-admission.
        let eff = q.req.prompt.len() + q.resume.len().saturating_sub(1);
        assert!(eff + 2 < self.cfg.max_seq,
                "effective prefill of {eff} tokens (prompt {} + resume {}) \
                 too long for context {}",
                q.req.prompt.len(), q.resume.len(), self.cfg.max_seq);
        self.metrics.start_clock();
        self.queue.push_back(q);
    }

    fn step(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        self.step_core(&mut |ev| {
            if let EngineEvent::Finished(c) = ev {
                out.push(c);
            }
        })?;
        Ok(out)
    }

    fn step_events(&mut self, sink: &mut dyn FnMut(EngineEvent)) -> Result<()> {
        self.step_core(sink)
    }

    fn cancel(&mut self, id: u64) -> bool {
        let known = self
            .slots
            .iter()
            .flatten()
            .any(|s| s.stop.is_none() && s.req.id == id)
            || self.queue.iter().any(|q| q.req.id == id);
        if known {
            self.cancels.insert(id);
        }
        known
    }

    fn pending(&self) -> usize {
        self.queue.len()
    }

    fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn batch_size(&self) -> usize {
        self.cfg.batch
    }

    fn max_prompt_len(&self) -> usize {
        // submit asserts prompt.len() + 2 < max_seq.
        self.cfg.max_seq.saturating_sub(3)
    }

    fn idle(&self) -> bool {
        // Off-slot completions still owed count as work: a step must run
        // to emit them.
        self.queue.is_empty() && DecodeEngine::active(self) == 0
            && self.done_early.is_empty()
    }

    fn page_geometry(&self) -> PageGeometry {
        let pool_pages = self.cfg.batch * self.cfg.pages_per_slot;
        if self.cfg.page_tokens == 0 {
            PageGeometry {
                pool_pages,
                tokens_per_page: 0,
                rows_per_seq: 0,
                fixed_pages_per_seq: self.cfg.pages_per_slot,
                slots: self.cfg.batch,
            }
        } else {
            PageGeometry {
                pool_pages,
                tokens_per_page: self.cfg.page_tokens,
                rows_per_seq: 1,
                fixed_pages_per_seq: 0,
                slots: self.cfg.batch,
            }
        }
    }

    fn min_priority(&self) -> Option<Priority> {
        self.slots
            .iter()
            .flatten()
            .filter(|s| s.stop.is_none())
            .map(|s| s.req.priority)
            .chain(self.queue.iter().map(|q| q.req.priority))
            .min()
    }

    fn take_metrics(&mut self) -> Metrics {
        std::mem::take(&mut self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
        Request::new(id, prompt, max_new)
    }

    #[test]
    fn generation_is_pure_function_of_prompt_and_seed() {
        let cfg = SimConfig::default();
        let p = vec![1, 42, 99, 7];
        let (a, sa) = SimEngine::expected_generation(&cfg, &p, 16);
        let (b, sb) = SimEngine::expected_generation(&cfg, &p, 16);
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        let other = SimConfig { seed: 1, ..cfg };
        let (c, _) = SimEngine::expected_generation(&other, &p, 16);
        assert_ne!(a, c, "seed must matter");
    }

    #[test]
    fn engine_matches_expected_generation_regardless_of_batching() {
        let cfg = SimConfig { batch: 2, ..Default::default() };
        let prompts: Vec<Vec<i32>> =
            (0..5).map(|i| vec![1, 10 + i, 20 + i, 3]).collect();
        let mut eng = SimEngine::new(cfg);
        for (i, p) in prompts.iter().enumerate() {
            DecodeEngine::submit(&mut eng, req(i as u64, p.clone(), 24));
        }
        let comps = eng.run_to_completion().unwrap();
        assert_eq!(comps.len(), 5);
        for c in comps {
            let (want, stop) =
                SimEngine::expected_generation(&cfg, &prompts[c.id as usize], 24);
            assert_eq!(c.generated, want, "id {}", c.id);
            assert_eq!(c.stop, stop);
        }
        assert_eq!(eng.metrics.requests_completed, 5);
        assert!(eng.metrics.tokens_generated > 0);
        assert_eq!(eng.pool_free(), eng.pool_capacity(), "page leak");
    }

    #[test]
    fn stop_reasons_cover_eos_and_max_new() {
        let cfg = SimConfig::default();
        let mut saw_eos = false;
        let mut saw_max = false;
        for i in 0..40 {
            let (g, stop) =
                SimEngine::expected_generation(&cfg, &[i, i + 1, i + 2], 12);
            match stop {
                StopReason::Eos => {
                    saw_eos = true;
                    assert_eq!(*g.last().unwrap(), Vocab::default().eos);
                }
                StopReason::MaxNewTokens => {
                    saw_max = true;
                    assert_eq!(g.len(), 12);
                }
                StopReason::ContextFull => {}
                StopReason::Cancelled
                | StopReason::DeadlineExceeded
                | StopReason::ResourceExhausted => {
                    unreachable!("control stops never come from decide()")
                }
            }
        }
        assert!(saw_eos && saw_max, "eos={saw_eos} max={saw_max}");
    }

    #[test]
    fn step_events_stream_started_tokens_finished_in_order() {
        let cfg = SimConfig::default();
        let prompt = vec![4, 9, 13];
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(7, prompt.clone(), 16));
        let mut events = Vec::new();
        while !DecodeEngine::idle(&eng) {
            eng.step_events(&mut |ev| events.push(ev)).unwrap();
        }
        assert!(matches!(events[0], EngineEvent::Started { id: 7 }),
                "first event must be Started, got {:?}", events[0]);
        let mut toks = Vec::new();
        let mut finished = None;
        for ev in &events[1..] {
            match ev {
                EngineEvent::Token { id, tok, index } => {
                    assert_eq!(*id, 7);
                    assert!(finished.is_none(), "token after Finished");
                    assert_eq!(*index, toks.len(), "token indices contiguous");
                    toks.push(*tok);
                }
                EngineEvent::Finished(c) => {
                    assert!(finished.is_none(), "duplicate Finished");
                    finished = Some(c.clone());
                }
                EngineEvent::Started { .. } => panic!("duplicate Started"),
                EngineEvent::Preempted { .. } => {
                    panic!("no preemption without memory pressure")
                }
            }
        }
        let c = finished.expect("no Finished event");
        assert_eq!(c.generated, toks,
                   "completion must equal the concatenated token events");
        let (want, stop) = SimEngine::expected_generation(&cfg, &prompt, 16);
        assert_eq!(toks, want);
        assert_eq!(c.stop, stop);
    }

    #[test]
    fn cancel_active_request_stops_within_one_step_and_frees_pages() {
        let cfg = SimConfig { batch: 1, eos_every: 0, ..Default::default() };
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(1, vec![2, 3, 5], 1000));
        // Admit + a few decode steps.
        for _ in 0..4 {
            DecodeEngine::step(&mut eng).unwrap();
        }
        assert_eq!(eng.pool_free(),
                   eng.pool_capacity() - cfg.pages_per_slot,
                   "active slot must hold pages");
        assert!(DecodeEngine::cancel(&mut eng, 1), "engine owns request 1");
        assert!(!DecodeEngine::cancel(&mut eng, 99), "unknown id refused");
        let comps = DecodeEngine::step(&mut eng).unwrap();
        assert_eq!(comps.len(), 1, "cancel resolves at the next step");
        assert_eq!(comps[0].stop, StopReason::Cancelled);
        assert_eq!(comps[0].generated.len(), 4,
                   "partial generation is returned");
        assert_eq!(eng.pool_free(), eng.pool_capacity(),
                   "cancelled slot must release its pages");
        assert_eq!(eng.metrics.requests_cancelled, 1);
        assert_eq!(eng.metrics.requests_completed, 0,
                   "cancelled requests are not served completions");
        assert!(DecodeEngine::idle(&eng));
    }

    #[test]
    fn cancel_queued_request_completes_empty_without_taking_a_slot() {
        // batch 1: the second request stays in the engine queue.
        let cfg = SimConfig { batch: 1, eos_every: 0, ..Default::default() };
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(1, vec![2, 3], 6));
        DecodeEngine::submit(&mut eng, req(2, vec![4, 5], 6));
        DecodeEngine::step(&mut eng).unwrap(); // admits 1 only
        assert_eq!(DecodeEngine::pending(&eng), 1);
        assert!(DecodeEngine::cancel(&mut eng, 2));
        let comps = DecodeEngine::step(&mut eng).unwrap();
        let c = comps.iter().find(|c| c.id == 2).expect("cancelled done");
        assert_eq!(c.stop, StopReason::Cancelled);
        assert!(c.generated.is_empty(), "never admitted, nothing generated");
        assert_eq!(DecodeEngine::pending(&eng), 0, "removed from queue");
        // Request 1 is untouched.
        let rest = eng.run_to_completion().unwrap();
        let c1 = rest.iter().find(|c| c.id == 1).expect("request 1 done");
        let (want, _) = SimEngine::expected_generation(&cfg, &[2, 3], 6);
        assert_eq!(c1.generated, want);
        assert_eq!(eng.pool_free(), eng.pool_capacity());
    }

    #[test]
    fn deadline_exceeded_stops_mid_decode_with_partial_output() {
        let cfg = SimConfig { batch: 1, eos_every: 0, step_delay_ms: 2,
                              ..Default::default() };
        let mut eng = SimEngine::new(cfg);
        let deadline = Instant::now() + Duration::from_millis(20);
        let r = req(5, vec![1, 2, 3], 100_000).with_deadline(deadline);
        DecodeEngine::submit(&mut eng, r);
        let comps = eng.run_to_completion().unwrap();
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].stop, StopReason::DeadlineExceeded);
        assert!(!comps[0].generated.is_empty(), "ran until the deadline");
        assert!(comps[0].generated.len() < 100_000, "stopped early");
        assert_eq!(eng.metrics.requests_deadline_expired, 1);
        assert_eq!(eng.pool_free(), eng.pool_capacity());
    }

    #[test]
    fn deadline_expired_while_queued_completes_without_admission() {
        let cfg = SimConfig { batch: 1, eos_every: 0, ..Default::default() };
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(1, vec![7, 8], 4));
        // Already expired when submitted; batch 1 keeps it queued.
        let expired = Instant::now() - Duration::from_millis(1);
        DecodeEngine::submit(&mut eng,
                             req(2, vec![9, 10], 4).with_deadline(expired));
        let comps = eng.run_to_completion().unwrap();
        let c = comps.iter().find(|c| c.id == 2).unwrap();
        assert_eq!(c.stop, StopReason::DeadlineExceeded);
        assert!(c.generated.is_empty());
        assert_eq!(comps.iter().filter(|c| c.id == 1).count(), 1);
        assert_eq!(eng.metrics.requests_deadline_expired, 1);
    }

    #[test]
    fn fault_schedule_builder_and_due() {
        let s = FaultSchedule::none();
        assert!(s.is_empty());
        assert_eq!(s.due(1).count(), 0);
        let s = s
            .at(3, Fault::Stall { steps: 2 })
            .at(3, Fault::FailAdmits { count: 1 })
            .at(9, Fault::ShrinkPool { pages: 4 });
        assert!(!s.is_empty());
        assert_eq!(s.due(3).count(), 2);
        assert_eq!(s.due(9).collect::<Vec<_>>(),
                   vec![Fault::ShrinkPool { pages: 4 }]);
        assert_eq!(s.due(4).count(), 0);
        // Seeded schedules are deterministic and non-empty.
        let a = FaultSchedule::seeded(11, 16);
        let b = FaultSchedule::seeded(11, 16);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert_ne!(a, FaultSchedule::seeded(12, 16));
    }

    #[test]
    fn fault_schedule_composes_crash_faults_onto_a_full_seeded_run() {
        // The 16-slot schedule must hold a seeded 3-fault run plus
        // Panic/Wedge chaos on top — the composition the supervisor
        // chaos matrix uses — with room to spare (13 on a seeded base).
        let mut s = FaultSchedule::seeded(7, 16)
            .at(12, Fault::Panic)
            .at(20, Fault::Wedge { ms: 50 });
        assert_eq!(s.due(12).collect::<Vec<_>>(), vec![Fault::Panic]);
        assert_eq!(s.due(20).collect::<Vec<_>>(),
                   vec![Fault::Wedge { ms: 50 }]);
        // Fill every remaining slot; the 17th insert must refuse loudly.
        for k in 0..11 {
            s = s.at(100 + k, Fault::Stall { steps: 1 });
        }
        assert_eq!((1..200).map(|t| s.due(t).count()).sum::<usize>(), 16);
        let full = s;
        let overflow = std::panic::catch_unwind(|| {
            full.at(999, Fault::Panic)
        });
        assert!(overflow.is_err(), "17th entry must panic, not drop");
    }

    #[test]
    fn panic_fault_panics_the_engine_at_its_step() {
        let cfg = SimConfig {
            batch: 1,
            eos_every: 0,
            faults: FaultSchedule::none().at(3, Fault::Panic),
            ..Default::default()
        };
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(1, vec![2, 3, 5], 12));
        let blew = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for _ in 0..3 {
                eng.step().unwrap();
            }
        }));
        assert!(blew.is_err(), "Panic fault must unwind at step 3");
    }

    #[test]
    fn wedge_fault_stalls_wall_clock_without_changing_output() {
        let prompt: Vec<i32> = vec![4, 9, 2];
        let wedged = SimConfig {
            batch: 1,
            eos_every: 0,
            faults: FaultSchedule::none().at(2, Fault::Wedge { ms: 60 }),
            ..Default::default()
        };
        let mut eng = SimEngine::new(wedged);
        DecodeEngine::submit(&mut eng, req(1, prompt.clone(), 8));
        let t0 = Instant::now();
        let mut comps = Vec::new();
        while !DecodeEngine::idle(&eng) {
            comps.extend(eng.step().unwrap());
        }
        assert!(t0.elapsed() >= Duration::from_millis(60),
                "wedge must actually block the step");
        assert_eq!(comps.len(), 1);
        let clean = SimConfig { faults: FaultSchedule::none(), ..wedged };
        let (want, want_stop) =
            SimEngine::expected_generation(&clean, &prompt, 8);
        assert_eq!(comps[0].generated, want,
                   "a wedge delays tokens, never changes them");
        assert_eq!(comps[0].stop, want_stop);
    }

    #[test]
    fn interactive_request_preempts_batch_and_stream_resumes_bit_identical() {
        // batch 1: the interactive arrival finds the engine full and must
        // evict the batch-priority occupant mid-decode.
        let cfg = SimConfig { batch: 1, eos_every: 0, ..Default::default() };
        let mut eng = SimEngine::new(cfg);
        let pa: Vec<i32> = vec![2, 3, 5];
        let pb: Vec<i32> = vec![7, 11];
        DecodeEngine::submit(&mut eng,
                             req(1, pa.clone(), 12)
                                 .with_priority(Priority::Batch));
        let mut events = Vec::new();
        for _ in 0..4 {
            eng.step_events(&mut |ev| events.push(ev)).unwrap(); // 4 tokens
        }
        assert_eq!(DecodeEngine::min_priority(&eng), Some(Priority::Batch));
        DecodeEngine::submit(&mut eng, req(2, pb.clone(), 6));
        while !DecodeEngine::idle(&eng) {
            eng.step_events(&mut |ev| events.push(ev)).unwrap();
        }
        // The batch request was preempted exactly once, then resumed.
        let preempts: Vec<u64> = events.iter().filter_map(|e| match e {
            EngineEvent::Preempted { id } => Some(*id),
            _ => None,
        }).collect();
        assert_eq!(preempts, vec![1], "batch victim preempted once");
        assert_eq!(eng.metrics.requests_preempted, 1);
        // Per-request token events: contiguous indices, bit-identical to
        // the unconstrained pure generation, exactly one Started each.
        for (id, prompt, max_new) in [(1u64, &pa, 12usize), (2, &pb, 6)] {
            let toks: Vec<i32> = events.iter().filter_map(|e| match e {
                EngineEvent::Token { id: i, tok, .. } if *i == id => Some(*tok),
                _ => None,
            }).collect();
            let idxs: Vec<usize> = events.iter().filter_map(|e| match e {
                EngineEvent::Token { id: i, index, .. } if *i == id => {
                    Some(*index)
                }
                _ => None,
            }).collect();
            assert_eq!(idxs, (0..toks.len()).collect::<Vec<_>>(),
                       "id {id}: indices contiguous across preemption");
            let starts = events.iter().filter(|e| {
                matches!(e, EngineEvent::Started { id: i } if *i == id)
            }).count();
            assert_eq!(starts, 1, "id {id}: resume must not re-emit Started");
            let (want, _) = SimEngine::expected_generation(&cfg, prompt, max_new);
            assert_eq!(toks, want, "id {id}: stream bit-identical");
            let done = events.iter().find_map(|e| match e {
                EngineEvent::Finished(c) if c.id == id => Some(c.clone()),
                _ => None,
            }).unwrap();
            assert_eq!(done.generated, want);
        }
        assert_eq!(eng.pool_free(), eng.pool_capacity(), "page leak");
    }

    #[test]
    fn spent_retry_budget_terminates_with_resource_exhausted() {
        let cfg = SimConfig { batch: 1, eos_every: 0, preempt_retries: 0,
                              ..Default::default() };
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng,
                             req(1, vec![2, 3], 50)
                                 .with_priority(Priority::Batch));
        for _ in 0..3 {
            DecodeEngine::step(&mut eng).unwrap();
        }
        DecodeEngine::submit(&mut eng, req(2, vec![4, 5], 6));
        let comps = eng.run_to_completion().unwrap();
        let c1 = comps.iter().find(|c| c.id == 1).unwrap();
        assert_eq!(c1.stop, StopReason::ResourceExhausted);
        assert_eq!(c1.generated.len(), 3,
                   "partial generation survives exhaustion");
        assert_eq!(eng.metrics.requests_exhausted, 1);
        assert_eq!(eng.metrics.requests_preempted, 0,
                   "exhaustion is terminal, not a requeue");
        let c2 = comps.iter().find(|c| c.id == 2).unwrap();
        let (want, _) = SimEngine::expected_generation(&cfg, &[4, 5], 6);
        assert_eq!(c2.generated, want, "the interactive winner is unharmed");
        assert_eq!(eng.pool_free(), eng.pool_capacity());
    }

    #[test]
    fn pool_shrink_fault_sheds_pages_and_everyone_still_terminates() {
        // Two active slots hold 8 pages; at step 5 the pool shrinks to 6,
        // forcing a deficit preemption of the youngest. Both requests
        // must still produce their exact streams.
        let cfg = SimConfig {
            batch: 2,
            eos_every: 0,
            faults: FaultSchedule::none()
                .at(5, Fault::ShrinkPool { pages: 6 }),
            ..Default::default()
        };
        let mut eng = SimEngine::new(cfg);
        let pa: Vec<i32> = vec![1, 2];
        let pb: Vec<i32> = vec![3, 4];
        DecodeEngine::submit(&mut eng, req(1, pa.clone(), 20));
        DecodeEngine::submit(&mut eng, req(2, pb.clone(), 20));
        let comps = eng.run_to_completion().unwrap();
        assert_eq!(comps.len(), 2);
        assert!(eng.metrics.requests_preempted >= 1, "shrink forced a preempt");
        for (id, prompt) in [(1u64, &pa), (2, &pb)] {
            let c = comps.iter().find(|c| c.id == id).unwrap();
            let (want, stop) = SimEngine::expected_generation(&cfg, prompt, 20);
            assert_eq!(c.generated, want, "id {id}");
            assert_eq!(c.stop, stop);
        }
        assert_eq!(eng.pool_capacity(), 6, "capacity stays shrunk");
        assert_eq!(eng.pool_free(), 6, "all pages back after drain");
        assert!(eng.metrics.pages_peak >= 8, "peak saw the full pool in use");
    }

    #[test]
    fn infeasible_request_is_resource_exhausted_not_starved() {
        // Length-projected paging: the long request can never fit the
        // pool, so it must terminate instead of queueing forever.
        let cfg = SimConfig { batch: 1, pages_per_slot: 2, page_tokens: 4,
                              eos_every: 0, ..Default::default() };
        let mut eng = SimEngine::new(cfg);
        // needs ceil((10 + 20 + 1) / 4) = 8 pages > 2.
        DecodeEngine::submit(&mut eng, req(1, vec![9; 10], 20));
        let comps = eng.run_to_completion().unwrap();
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].stop, StopReason::ResourceExhausted);
        assert!(comps[0].generated.is_empty());
        assert_eq!(eng.metrics.requests_exhausted, 1);
        // A fitting request still runs: ceil((2 + 4 + 1) / 4) = 2 pages.
        DecodeEngine::submit(&mut eng, req(2, vec![1, 2], 4));
        let comps = eng.run_to_completion().unwrap();
        let (want, _) = SimEngine::expected_generation(&cfg, &[1, 2], 4);
        assert_eq!(comps[0].generated, want);
        assert_eq!(eng.pool_free(), eng.pool_capacity());
    }

    #[test]
    fn stall_and_admit_faults_delay_but_do_not_change_output() {
        let faulty = SimConfig {
            batch: 1,
            eos_every: 0,
            faults: FaultSchedule::none()
                .at(1, Fault::Stall { steps: 2 })
                .at(4, Fault::FailAdmits { count: 1 }),
            ..Default::default()
        };
        let clean = SimConfig { faults: FaultSchedule::none(), ..faulty };
        let run = |cfg: SimConfig| {
            let mut eng = SimEngine::new(cfg);
            DecodeEngine::submit(&mut eng, req(1, vec![5, 6], 8));
            DecodeEngine::submit(&mut eng, req(2, vec![7, 8], 8));
            let mut comps = eng.run_to_completion().unwrap();
            comps.sort_by_key(|c| c.id);
            (comps, eng.pool_free(), eng.pool_capacity())
        };
        let (fa, ffree, fcap) = run(faulty);
        let (ca, _, _) = run(clean);
        assert_eq!(fa.len(), 2);
        assert_eq!(ffree, fcap);
        for (f, c) in fa.iter().zip(ca.iter()) {
            assert_eq!(f.id, c.id);
            assert_eq!(f.generated, c.generated,
                       "faults may delay but never change tokens");
            assert_eq!(f.stop, c.stop);
        }
    }

    #[test]
    fn chunked_prefill_interleaves_decode_with_admission() {
        // A long admission must not stall the running batch: with a
        // 4-token chunk, a 10-token prompt takes ceil(10/4) = 3 steps to
        // admit, and the already-running slot decodes on every one of
        // them — the head-of-line stall the monolithic path had.
        let cfg = SimConfig { batch: 2, eos_every: 0, prefill_chunk: 4,
                              ..Default::default() };
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(1, vec![2, 3], 64));
        eng.step_events(&mut |_| {}).unwrap(); // admit 1 + its first token
        let long: Vec<i32> = (0..10).collect();
        DecodeEngine::submit(&mut eng, req(2, long.clone(), 4));
        let mut started_at = None;
        for s in 0..3 {
            let mut toks_1 = 0;
            eng.step_events(&mut |ev| match ev {
                EngineEvent::Token { id: 1, .. } => toks_1 += 1,
                EngineEvent::Started { id: 2 } => started_at = Some(s),
                _ => {}
            }).unwrap();
            assert_eq!(toks_1, 1,
                       "running slot decodes during prefill step {s}");
        }
        assert_eq!(started_at, Some(2),
                   "10-token prompt over 4-token chunks admits on step 3");
        let comps = eng.run_to_completion().unwrap();
        for c in comps {
            let (prompt, max_new) =
                if c.id == 1 { (vec![2, 3], 64) } else { (long.clone(), 4) };
            let (want, _) = SimEngine::expected_generation(&cfg, &prompt,
                                                           max_new);
            assert_eq!(c.generated, want, "id {}", c.id);
        }
        assert_eq!(eng.pool_free(), eng.pool_capacity(), "page leak");
    }

    #[test]
    fn cancel_mid_prefill_frees_pages_without_emitting() {
        let cfg = SimConfig { batch: 1, eos_every: 0, prefill_chunk: 2,
                              ..Default::default() };
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(7, (0..10).collect(), 8));
        let mut events = Vec::new();
        eng.step_events(&mut |ev| events.push(ev)).unwrap();
        assert!(events.is_empty(), "half-prefilled slot emits nothing");
        assert_eq!(eng.pool_free(), 0, "admitted slot holds its pages");
        assert!(DecodeEngine::cancel(&mut eng, 7));
        eng.step_events(&mut |ev| events.push(ev)).unwrap();
        assert_eq!(events.len(), 1);
        let EngineEvent::Finished(c) = &events[0] else {
            panic!("cancel mid-prefill must finish, not stream");
        };
        assert_eq!(c.stop, StopReason::Cancelled);
        assert!(c.generated.is_empty(), "no tokens were ever streamed");
        assert_eq!(eng.pool_free(), eng.pool_capacity(), "page leak");
        assert!(DecodeEngine::idle(&eng));
    }

    #[test]
    fn deadline_mid_prefill_reaps_through_the_same_path() {
        // 40 tokens at 1 token per 2ms step outlives the 8ms deadline,
        // so the stop lands on a half-prefilled slot (or, on a very slow
        // machine, while still queued — same observable outcome).
        let cfg = SimConfig { batch: 1, eos_every: 0, prefill_chunk: 1,
                              step_delay_ms: 2, ..Default::default() };
        let mut eng = SimEngine::new(cfg);
        let r = req(3, (0..40).collect(), 8)
            .with_deadline(Instant::now() + Duration::from_millis(8));
        DecodeEngine::submit(&mut eng, r);
        let mut events = Vec::new();
        while !DecodeEngine::idle(&eng) {
            eng.step_events(&mut |ev| events.push(ev)).unwrap();
        }
        assert_eq!(events.len(), 1);
        let EngineEvent::Finished(c) = &events[0] else {
            panic!("expired mid-prefill must finish without streaming");
        };
        assert_eq!(c.stop, StopReason::DeadlineExceeded);
        assert!(c.generated.is_empty());
        assert_eq!(eng.pool_free(), eng.pool_capacity(), "page leak");
    }

    #[test]
    fn preempt_mid_prefill_requeues_and_still_streams_bit_identical() {
        let cfg = SimConfig { batch: 1, eos_every: 0, prefill_chunk: 2,
                              ..Default::default() };
        let mut eng = SimEngine::new(cfg);
        let pa: Vec<i32> = (0..9).collect();
        let pb: Vec<i32> = vec![7, 11];
        DecodeEngine::submit(&mut eng,
                             req(1, pa.clone(), 5)
                                 .with_priority(Priority::Batch));
        let mut events = Vec::new();
        for _ in 0..2 {
            eng.step_events(&mut |ev| events.push(ev)).unwrap();
        }
        assert!(events.is_empty(), "still half-prefilled: nothing streamed");
        // The interactive arrival evicts the half-prefilled batch slot.
        DecodeEngine::submit(&mut eng, req(2, pb.clone(), 3));
        while !DecodeEngine::idle(&eng) {
            eng.step_events(&mut |ev| events.push(ev)).unwrap();
        }
        let preempts = events.iter().filter(|e| {
            matches!(e, EngineEvent::Preempted { id: 1 })
        }).count();
        assert_eq!(preempts, 1, "mid-prefill victim preempted once");
        // The victim had streamed nothing, so re-admission is a fresh
        // start: exactly one Started, full bit-identical stream.
        for (id, prompt, max_new) in [(1u64, &pa, 5usize), (2, &pb, 3)] {
            let toks: Vec<i32> = events.iter().filter_map(|e| match e {
                EngineEvent::Token { id: i, tok, .. } if *i == id => Some(*tok),
                _ => None,
            }).collect();
            let starts = events.iter().filter(|e| {
                matches!(e, EngineEvent::Started { id: i } if *i == id)
            }).count();
            assert_eq!(starts, 1, "id {id}: exactly one Started");
            let (want, _) = SimEngine::expected_generation(&cfg, prompt,
                                                           max_new);
            assert_eq!(toks, want, "id {id}: stream bit-identical");
        }
        assert_eq!(eng.pool_free(), eng.pool_capacity(), "page leak");
    }

    #[test]
    fn resume_tokens_count_against_the_context_guard() {
        let cfg = SimConfig { batch: 1, max_seq: 16, ..Default::default() };
        let mk = |resume_len: usize| QueuedReq {
            req: req(1, vec![1, 2, 3, 4, 5], 32),
            arrived: Instant::now(),
            resume: vec![9; resume_len],
            first_token_at: None,
            retries: 1,
            sticky: false,
        };
        // Boundary pass: eff = 5 + (9 - 1) = 13 and 13 + 2 < 16.
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit_queued(&mut eng, mk(9));
        // One more resume token overruns: eff = 14, 14 + 2 == 16.
        let denied = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let mut eng = SimEngine::new(cfg);
                DecodeEngine::submit_queued(&mut eng, mk(10));
            }));
        assert!(denied.is_err(),
                "resume span past the context window must be rejected");
    }

    #[test]
    fn chunked_and_monolithic_prefill_produce_identical_streams() {
        let chunked = SimConfig { batch: 2, eos_every: 0, prefill_chunk: 4,
                                  ..Default::default() };
        let mono = SimConfig { prefill_chunk: 0, ..chunked };
        let prompts: Vec<Vec<i32>> =
            (0..4).map(|i| (0..18 + i).collect()).collect();
        let run = |cfg: SimConfig| {
            let mut eng = SimEngine::new(cfg);
            for (i, p) in prompts.iter().enumerate() {
                DecodeEngine::submit(&mut eng, req(i as u64, p.clone(), 6));
            }
            let mut comps = eng.run_to_completion().unwrap();
            comps.sort_by_key(|c| c.id);
            (comps, eng.metrics.prefill_chunks, eng.metrics.prefill_tokens)
        };
        let (a, chunks_a, toks_a) = run(chunked);
        let (b, chunks_b, toks_b) = run(mono);
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.generated, y.generated, "id {}", x.id);
            assert_eq!(x.stop, y.stop, "id {}", x.id);
        }
        assert_eq!(toks_a, toks_b, "same tokens prefilled either way");
        assert_eq!(toks_a, (18 + 19 + 20 + 21) as u64);
        assert!(chunks_a > chunks_b,
                "4-token chunks over ~20-token prompts take more chunk steps");
    }

    #[test]
    fn page_geometry_reflects_paging_model() {
        let flat = SimEngine::new(SimConfig { batch: 2, pages_per_slot: 4,
                                              ..Default::default() });
        let g = DecodeEngine::page_geometry(&flat);
        assert_eq!(g.pool_pages, 8);
        assert_eq!(g.fixed_pages_per_seq, 4);
        assert_eq!(g.slots, 2);
        assert_eq!(g.project(100, 100), 4, "flat model ignores lengths");
        let tok = SimEngine::new(SimConfig { batch: 2, pages_per_slot: 4,
                                             page_tokens: 8,
                                             ..Default::default() });
        let g = DecodeEngine::page_geometry(&tok);
        assert_eq!(g.pool_pages, 8);
        assert_eq!(g.tokens_per_page, 8);
        assert_eq!(g.project(8, 55), 8, "64 tokens over 8-token pages");
    }

    /// A prefix-cache config with deterministic step counts (EOS off).
    fn prefix_cfg() -> SimConfig {
        SimConfig {
            batch: 2,
            page_tokens: 4,
            pages_per_slot: 8, // capacity = 16 pages
            prefix_cache: true,
            prefill_chunk: 8,
            eos_every: 0,
            ..Default::default()
        }
    }

    #[test]
    fn prefix_reuse_skips_prefill_work_and_streams_bit_identical() {
        let cfg = prefix_cfg();
        let prompt: Vec<i32> = (0..17).map(|i| 100 + i).collect(); // 4 blocks + 1
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(0, prompt.clone(), 8));
        let cold = eng.run_to_completion().unwrap();
        let cold_prefill = eng.metrics.prefill_tokens;
        assert_eq!(cold_prefill, 17);
        assert_eq!(eng.prefix_cached_blocks(), 4,
                   "every full prompt block published");
        assert_eq!(eng.metrics.prefix_hits, 0);
        // Warm run: same prompt, different request.
        DecodeEngine::submit(&mut eng, req(1, prompt.clone(), 8));
        let warm = eng.run_to_completion().unwrap();
        assert_eq!(warm[0].generated, cold[0].generated,
                   "warm stream bit-identical to cold");
        let (want, _) = SimEngine::expected_generation(&cfg, &prompt, 8);
        assert_eq!(warm[0].generated, want);
        assert_eq!(eng.metrics.prefix_hits, 1);
        assert_eq!(eng.metrics.prefix_blocks_reused, 4);
        assert_eq!(eng.metrics.prefill_tokens, cold_prefill + 1,
                   "warm prefill folds only the 1-token tail");
        // Pool balance: cached blocks are accounted, not leaked…
        assert_eq!(eng.pool_free() + eng.prefix_cached_blocks(),
                   eng.pool_capacity());
        // …and fully reclaimable once evicted.
        assert_eq!(eng.prefix_evict_all(), 4);
        assert_eq!(eng.pool_free(), eng.pool_capacity());
        assert_eq!(eng.metrics.prefix_evictions, 4);
    }

    #[test]
    fn divergent_prompt_reuses_only_the_shared_blocks() {
        let cfg = prefix_cfg();
        let a: Vec<i32> = (0..12).map(|i| 100 + i).collect(); // 3 aligned blocks
        let mut b = a.clone();
        b[9] += 1; // diverges inside block 2
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(0, a.clone(), 6));
        eng.run_to_completion().unwrap();
        assert_eq!(eng.prefix_cached_blocks(), 3);
        DecodeEngine::submit(&mut eng, req(1, b.clone(), 6));
        let warm = eng.run_to_completion().unwrap();
        assert_eq!(eng.metrics.prefix_blocks_reused, 2,
                   "blocks 0–1 shared; block 2 diverges");
        // The divergent block is freshly folded and published under its
        // own content hash — both variants now coexist in the cache.
        assert_eq!(eng.prefix_cached_blocks(), 4);
        let (want, _) = SimEngine::expected_generation(&cfg, &b, 6);
        assert_eq!(warm[0].generated, want, "divergence is never papered over");
    }

    #[test]
    fn fully_aligned_prompt_still_folds_its_last_block() {
        // A prompt whose every block is cached must still fold at least
        // one token so admission samples a first token through the
        // normal chunk machinery (and TTFT stays well-defined).
        let cfg = prefix_cfg();
        let prompt: Vec<i32> = (0..12).map(|i| 7 * i).collect(); // aligned
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(0, prompt.clone(), 6));
        let cold = eng.run_to_completion().unwrap();
        DecodeEngine::submit(&mut eng, req(1, prompt.clone(), 6));
        let warm = eng.run_to_completion().unwrap();
        assert_eq!(eng.metrics.prefix_blocks_reused, 2,
                   "reuse is capped one block short of the full prompt");
        assert_eq!(warm[0].generated, cold[0].generated);
        assert_eq!(eng.pool_free() + eng.prefix_cached_blocks(),
                   eng.pool_capacity());
    }

    #[test]
    fn cancel_mid_prefill_with_reuse_unpins_without_leaking() {
        // A warm admission pins its reuse chain; cancelling the request
        // while it is still half-prefilled must drop those pins through
        // the same reap path as a served request — the cached blocks
        // stay resident (and evictable), and the page gauge balances.
        let cfg = SimConfig { prefill_chunk: 2, ..prefix_cfg() };
        // 5 full blocks + a 3-token tail: the warm admission reuses all
        // 5 blocks and its remaining 3-token fold spans two 2-token
        // chunks, so after one step the slot is genuinely mid-prefill.
        let prompt: Vec<i32> = (0..23).map(|i| 100 + i).collect();
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(0, prompt.clone(), 8));
        eng.run_to_completion().unwrap();
        assert_eq!(eng.prefix_cached_blocks(), 5);
        DecodeEngine::submit(&mut eng, req(1, prompt.clone(), 8));
        DecodeEngine::step(&mut eng).unwrap(); // admit + first 2-token chunk
        assert!(DecodeEngine::cancel(&mut eng, 1));
        let comps = eng.run_to_completion().unwrap();
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].stop, StopReason::Cancelled);
        assert!(comps[0].generated.is_empty(), "cancelled before first token");
        assert_eq!(eng.pool_free() + eng.prefix_cached_blocks(),
                   eng.pool_capacity(), "no page leak after cancel");
        // All pins dropped: the whole cache drains on demand.
        assert_eq!(eng.prefix_evict_all(), 5);
        assert_eq!(eng.pool_free(), eng.pool_capacity());
    }

    #[test]
    fn pool_shrink_evicts_cached_blocks_before_preempting() {
        // Cache pages are the cheapest thing to give back: a shrink that
        // the evictable cache can absorb must not preempt live slots.
        let cfg = SimConfig {
            faults: FaultSchedule::none().at(15, Fault::ShrinkPool { pages: 5 }),
            ..prefix_cfg()
        };
        let warmup: Vec<i32> = (0..17).map(|i| 100 + i).collect();
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(0, warmup.clone(), 8));
        eng.run_to_completion().unwrap();
        assert_eq!(eng.prefix_cached_blocks(), 4);
        // An unrelated request is mid-decode when the pool shrinks to 5:
        // held(3) + cached(4) exceeds it by 2, shed by evicting 2 blocks.
        let small: Vec<i32> = vec![9, 8, 7];
        DecodeEngine::submit(&mut eng, req(1, small.clone(), 8));
        let comps = eng.run_to_completion().unwrap();
        let (want, _) = SimEngine::expected_generation(&cfg, &small, 8);
        assert_eq!(comps[0].generated, want, "shrink never corrupts streams");
        assert_eq!(eng.metrics.prefix_evictions, 2,
                   "deficit shed from the cache");
        assert_eq!(eng.metrics.requests_preempted, 0,
                   "no live slot paid for what the cache could yield");
        assert_eq!(eng.pool_free() + eng.prefix_cached_blocks(),
                   eng.pool_capacity());
    }

    #[test]
    fn preempting_a_warm_slot_never_evicts_its_pinned_chain_or_leaks_pins() {
        // A shrink the cache cannot absorb (every cached block is pinned
        // by the live warm slot) must preempt the slot — not evict pages
        // it still maps — and the preempt must drop the pins so the
        // whole cache is reclaimable afterwards.
        let cfg = SimConfig {
            batch: 1, // capacity = 8 pages
            faults: FaultSchedule::none().at(14, Fault::ShrinkPool { pages: 5 }),
            ..prefix_cfg()
        };
        let prompt: Vec<i32> = (0..17).map(|i| 100 + i).collect();
        let mut eng = SimEngine::new(cfg);
        DecodeEngine::submit(&mut eng, req(0, prompt.clone(), 8));
        let cold = eng.run_to_completion().unwrap();
        assert_eq!(eng.prefix_cached_blocks(), 4);
        // Warm re-run holds 3 private + 4 pinned cache pages when the
        // pool shrinks to 5 at step 14 (mid-decode, 3 tokens out). The
        // cache yields nothing (all pinned), so the slot is preempted;
        // the shrunken pool can never re-fit its 7-page projection, so
        // it terminates ResourceExhausted carrying a bit-exact partial
        // stream.
        DecodeEngine::submit(&mut eng, req(1, prompt.clone(), 8));
        let comps = eng.run_to_completion().unwrap();
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].stop, StopReason::ResourceExhausted);
        assert_eq!(comps[0].generated, cold[0].generated[..3],
                   "partial stream is a bit-exact prefix");
        assert_eq!(eng.metrics.requests_preempted, 1);
        assert_eq!(eng.metrics.prefix_evictions, 0,
                   "pinned blocks are never evicted out from under a slot");
        assert_eq!(eng.pool_free() + eng.prefix_cached_blocks(),
                   eng.pool_capacity());
        // Pins fully dropped on the preempt/exhaust path: every cached
        // block is evictable again.
        assert_eq!(eng.prefix_evict_all(), 4);
        assert_eq!(eng.pool_free(), eng.pool_capacity());
    }
}
