//! Deterministic host-only decode engine for the serving test harness.
//!
//! [`SimEngine`] mirrors the PJRT engine's continuous-batching control
//! flow exactly — bounded batch slots, admit+prefill when slots free up,
//! one decode token per step for every running slot, stop on EOS /
//! max-new / context-full, completion reaping, metrics recording — but
//! replaces the device model with a pure token function: every generated
//! token is a deterministic mix of the engine seed and the request's
//! prompt. The output for a request therefore depends **only** on the
//! request content and the engine configuration, never on batch
//! placement, admission order, or shard assignment — which is precisely
//! the property that makes 1-shard vs N-shard completion parity provable
//! in `rust/tests/serving.rs`. (The real engine has the same property
//! under greedy sampling; see `rust/tests/engine.rs`.)

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::metrics::Metrics;
use super::request::{Completion, Request, SeqStats, StopReason};
use super::DecodeEngine;
use crate::workload::Vocab;

/// Domain-separation tag folded into every slot's initial state, so a
/// seed of 0 still produces a non-trivial token stream.
const SIM_TAG: u64 = 0x5EE7_A77E_0DEC_0DE5;

/// SplitMix64 finalizer — the per-token mixing function.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Concurrent batch slots.
    pub batch: usize,
    /// Context window (tokens); mirrors the engine's ContextFull stop.
    pub max_seq: usize,
    /// Engine seed; part of every slot's token-function state.
    pub seed: u64,
    /// Minimum generated tokens before EOS may fire.
    pub min_gen: usize,
    /// EOS fires when `state % eos_every == 0` (0 disables EOS).
    pub eos_every: u64,
    /// Test-harness knob: sleep this long per `step` (0 = off), so
    /// requests stay in flight long enough for timing-dependent serving
    /// behaviour (idle timeouts, admission backpressure, work stealing)
    /// to be observable deterministically. Not part of the token
    /// function — output parity is unaffected.
    pub step_delay_ms: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { batch: 4, max_seq: 512, seed: 0, min_gen: 4, eos_every: 23,
                    step_delay_ms: 0 }
    }
}

struct SimSlot {
    req: Request,
    admitted: Instant,
    first_token: Option<Instant>,
    /// Rolling token-function state (seed + prompt hash + emitted tokens).
    state: u64,
    /// Tokens whose KV would be cached: prompt + generated minus the
    /// just-emitted token (exactly the engine's `Slot::len` semantics,
    /// so ContextFull fires on the same step).
    len: usize,
    generated: Vec<i32>,
    stop: Option<StopReason>,
}

pub struct SimEngine {
    pub cfg: SimConfig,
    slots: Vec<Option<SimSlot>>,
    queue: VecDeque<(Request, Instant)>,
    pub metrics: Metrics,
    pub vocab: Vocab,
}

impl SimEngine {
    pub fn new(cfg: SimConfig) -> SimEngine {
        SimEngine {
            slots: (0..cfg.batch).map(|_| None).collect(),
            queue: VecDeque::new(),
            metrics: Metrics::new(),
            vocab: Vocab::default(),
            cfg,
        }
    }

    /// The deterministic generation a request would produce, computed
    /// directly (tests compare engine output against this).
    pub fn expected_generation(cfg: &SimConfig, prompt: &[i32],
                               max_new: usize) -> (Vec<i32>, StopReason) {
        let vocab = Vocab::default();
        let mut state = cfg.seed ^ SIM_TAG;
        for &t in prompt {
            state = mix(state ^ t as u64);
        }
        let mut generated = Vec::new();
        let mut len = prompt.len();
        loop {
            if !generated.is_empty() {
                // The previous token enters the cache before the next
                // decode step (engine decode semantics).
                len += 1;
            }
            state = mix(state);
            let tok = Self::token_from(cfg, &vocab, state, generated.len());
            generated.push(tok);
            if let Some(stop) = StopReason::decide(tok, vocab.eos, generated.len(),
                                                   max_new, len, cfg.max_seq) {
                return (generated, stop);
            }
        }
    }

    fn token_from(cfg: &SimConfig, vocab: &Vocab, state: u64,
                  n_generated: usize) -> i32 {
        if cfg.eos_every > 0 && n_generated >= cfg.min_gen
            && state % cfg.eos_every == 0
        {
            return vocab.eos;
        }
        // Keep clear of the control-token range (ids 0..8).
        8 + (state % 200) as i32
    }

    fn admit_and_prefill(&mut self) {
        let t0 = Instant::now();
        let cfg = self.cfg;
        let vocab = self.vocab;
        let mut admitted_any = false;
        for entry in self.slots.iter_mut() {
            if entry.is_none() {
                if let Some((req, admitted)) = self.queue.pop_front() {
                    // "Prefill": fold the prompt into the token-function
                    // state and emit the first token.
                    let mut state = cfg.seed ^ SIM_TAG;
                    for &t in &req.prompt {
                        state = mix(state ^ t as u64);
                    }
                    let mut slot = SimSlot {
                        state,
                        len: req.prompt.len(),
                        generated: Vec::new(),
                        stop: None,
                        first_token: None,
                        admitted,
                        req,
                    };
                    Self::emit(&cfg, &vocab, &mut slot);
                    slot.first_token = Some(Instant::now());
                    *entry = Some(slot);
                    admitted_any = true;
                }
            }
        }
        if admitted_any {
            self.metrics.prefill_s.push(t0.elapsed().as_secs_f64());
        }
    }

    /// Generate one token. `slot.len` is NOT advanced here — the caller
    /// accounts cache growth (decode caches the previous token first),
    /// mirroring the engine's prefill/decode split.
    fn emit(cfg: &SimConfig, vocab: &Vocab, slot: &mut SimSlot) {
        slot.state = mix(slot.state);
        let tok = Self::token_from(cfg, vocab, slot.state, slot.generated.len());
        slot.generated.push(tok);
        slot.stop = StopReason::decide(tok, vocab.eos, slot.generated.len(),
                                       slot.req.max_new, slot.len, cfg.max_seq);
    }

    fn decode_step(&mut self) {
        let t0 = Instant::now();
        let cfg = self.cfg;
        let vocab = self.vocab;
        for slot in self.slots.iter_mut().flatten() {
            // The previous step's token enters the cache, then the next
            // token is generated (engine decode order).
            slot.len += 1;
            Self::emit(&cfg, &vocab, slot);
        }
        self.metrics.decode_step_s.push(t0.elapsed().as_secs_f64());
    }

    fn reap(&mut self) -> Vec<Completion> {
        let mut out = Vec::new();
        for entry in self.slots.iter_mut() {
            let finished = entry
                .as_ref()
                .map(|s| s.stop.is_some())
                .unwrap_or(false);
            if finished {
                let slot = entry.take().unwrap();
                let now = Instant::now();
                let ttft = slot
                    .first_token
                    .map(|t| t - slot.admitted)
                    .unwrap_or_default();
                let e2e = now - slot.admitted;
                self.metrics.record_completion(ttft, e2e, slot.generated.len());
                out.push(Completion {
                    id: slot.req.id,
                    prompt_len: slot.req.prompt.len(),
                    generated: slot.generated,
                    stop: slot.stop.unwrap(),
                    ttft,
                    e2e,
                    stats: SeqStats::default(),
                });
            }
        }
        out
    }

    /// Run everything currently queued to completion.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !DecodeEngine::idle(self) {
            out.extend(DecodeEngine::step(self)?);
        }
        Ok(out)
    }
}

impl DecodeEngine for SimEngine {
    fn submit_at(&mut self, req: Request, arrived: Instant) {
        assert!(req.prompt.len() + 2 < self.cfg.max_seq,
                "prompt {} too long for context {}", req.prompt.len(),
                self.cfg.max_seq);
        self.metrics.start_clock();
        self.queue.push_back((req, arrived));
    }

    fn step(&mut self) -> Result<Vec<Completion>> {
        if self.cfg.step_delay_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(
                self.cfg.step_delay_ms));
        }
        if !self.queue.is_empty() && self.slots.iter().any(|s| s.is_none()) {
            self.admit_and_prefill();
        } else if self.active() > 0 {
            self.decode_step();
        }
        Ok(self.reap())
    }

    fn pending(&self) -> usize {
        self.queue.len()
    }

    fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn batch_size(&self) -> usize {
        self.cfg.batch
    }

    fn max_prompt_len(&self) -> usize {
        // submit asserts prompt.len() + 2 < max_seq.
        self.cfg.max_seq.saturating_sub(3)
    }

    fn take_metrics(&mut self) -> Metrics {
        std::mem::take(&mut self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
        Request { id, prompt, max_new }
    }

    #[test]
    fn generation_is_pure_function_of_prompt_and_seed() {
        let cfg = SimConfig::default();
        let p = vec![1, 42, 99, 7];
        let (a, sa) = SimEngine::expected_generation(&cfg, &p, 16);
        let (b, sb) = SimEngine::expected_generation(&cfg, &p, 16);
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        let other = SimConfig { seed: 1, ..cfg };
        let (c, _) = SimEngine::expected_generation(&other, &p, 16);
        assert_ne!(a, c, "seed must matter");
    }

    #[test]
    fn engine_matches_expected_generation_regardless_of_batching() {
        let cfg = SimConfig { batch: 2, ..Default::default() };
        let prompts: Vec<Vec<i32>> =
            (0..5).map(|i| vec![1, 10 + i, 20 + i, 3]).collect();
        let mut eng = SimEngine::new(cfg);
        for (i, p) in prompts.iter().enumerate() {
            DecodeEngine::submit(&mut eng, req(i as u64, p.clone(), 24));
        }
        let comps = eng.run_to_completion().unwrap();
        assert_eq!(comps.len(), 5);
        for c in comps {
            let (want, stop) =
                SimEngine::expected_generation(&cfg, &prompts[c.id as usize], 24);
            assert_eq!(c.generated, want, "id {}", c.id);
            assert_eq!(c.stop, stop);
        }
        assert_eq!(eng.metrics.requests_completed, 5);
        assert!(eng.metrics.tokens_generated > 0);
    }

    #[test]
    fn stop_reasons_cover_eos_and_max_new() {
        let cfg = SimConfig::default();
        let mut saw_eos = false;
        let mut saw_max = false;
        for i in 0..40 {
            let (g, stop) =
                SimEngine::expected_generation(&cfg, &[i, i + 1, i + 2], 12);
            match stop {
                StopReason::Eos => {
                    saw_eos = true;
                    assert_eq!(*g.last().unwrap(), Vocab::default().eos);
                }
                StopReason::MaxNewTokens => {
                    saw_max = true;
                    assert_eq!(g.len(), 12);
                }
                StopReason::ContextFull => {}
            }
        }
        assert!(saw_eos && saw_max, "eos={saw_eos} max={saw_max}");
    }
}
