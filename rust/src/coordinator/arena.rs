//! Persistent staging arenas for the decode hot path.
//!
//! The seed engine allocated and zero-filled fresh `k_sel`/`v_sel`/`mask`
//! (and, on the dense path, full-context `kc`/`vc`) staging buffers at
//! every `run_attention` call — for every layer of every decode token.
//! Those buffers are the largest host-side objects on the step, so the
//! allocator + memset dominated coordinator time and buried the paper's
//! I/O argument (cost should scale with the token *budget*).
//!
//! [`StagingArena`] owns one buffer set per staging shape for the
//! engine's lifetime. Each set tracks a *dirty extent* per `(batch,
//! head)` row — how many staged tokens the previous use wrote — and
//! `acquire` zeroes exactly those extents, restoring the all-zeros
//! invariant the executables expect while touching only bytes that were
//! actually written. Steady-state decode therefore performs zero heap
//! allocation in the gather stage, and clearing cost scales with the
//! selection budget, not the staging capacity.
//!
//! The arena is pure host code (no PJRT dependency), so the
//! `decode_hot_path` bench exercises it under the default feature set.

use std::collections::HashMap;

use crate::runtime::tensor::{Data, HostTensor};

/// One sparse staging shape: `k`/`v` are `[b, heads, t_cap, dh]`, `mask`
/// is `[b, heads, t_cap]`.
pub struct SparseStaging {
    pub k: HostTensor,
    pub v: HostTensor,
    pub mask: HostTensor,
    /// Tokens written per `(b, head)` row at the last use.
    dirty: Vec<usize>,
    t_cap: usize,
    dh: usize,
}

fn f32_mut(t: &mut HostTensor) -> &mut [f32] {
    match &mut t.data {
        Data::F32(v) => v.as_mut_slice(),
        Data::I32(_) => unreachable!("staging tensors are f32"),
    }
}

impl SparseStaging {
    fn new(b: usize, heads: usize, t_cap: usize, dh: usize) -> SparseStaging {
        SparseStaging {
            k: HostTensor::zeros_f32(vec![b, heads, t_cap, dh]),
            v: HostTensor::zeros_f32(vec![b, heads, t_cap, dh]),
            mask: HostTensor::zeros_f32(vec![b, heads, t_cap]),
            dirty: vec![0; b * heads],
            t_cap,
            dh,
        }
    }

    /// Zero the previously-written extents, restoring all-zeros.
    fn reset(&mut self) {
        let (t_cap, dh) = (self.t_cap, self.dh);
        let k = f32_mut(&mut self.k);
        let v = f32_mut(&mut self.v);
        let m = f32_mut(&mut self.mask);
        for (r, d) in self.dirty.iter_mut().enumerate() {
            if *d > 0 {
                let o = r * t_cap * dh;
                k[o..o + *d * dh].fill(0.0);
                v[o..o + *d * dh].fill(0.0);
                m[r * t_cap..r * t_cap + *d].fill(0.0);
                *d = 0;
            }
        }
    }

    /// Mutable views for the gather stage: `(k, v, mask, dirty)`. The
    /// caller must record, for every row it writes, the staged token
    /// count in `dirty[b * heads + row]` so the next acquire can clear
    /// it.
    pub fn parts_mut(
        &mut self,
    ) -> (&mut [f32], &mut [f32], &mut [f32], &mut [usize]) {
        let k = f32_mut(&mut self.k);
        let v = f32_mut(&mut self.v);
        let m = f32_mut(&mut self.mask);
        (k, v, m, &mut self.dirty[..])
    }
}

/// Dense staging: `k`/`v` are `[b, hkv, s, dh]`, `seq_len` is `[b]` i32.
pub struct DenseStaging {
    pub k: HostTensor,
    pub v: HostTensor,
    pub seq_len: HostTensor,
    /// Tokens written per `(b, kv head)` row at the last use.
    dirty: Vec<usize>,
    s: usize,
    dh: usize,
}

impl DenseStaging {
    fn new(b: usize, hkv: usize, s: usize, dh: usize) -> DenseStaging {
        DenseStaging {
            k: HostTensor::zeros_f32(vec![b, hkv, s, dh]),
            v: HostTensor::zeros_f32(vec![b, hkv, s, dh]),
            seq_len: HostTensor::i32(vec![b], vec![0; b]),
            dirty: vec![0; b * hkv],
            s,
            dh,
        }
    }

    fn reset(&mut self) {
        let (s, dh) = (self.s, self.dh);
        let k = f32_mut(&mut self.k);
        let v = f32_mut(&mut self.v);
        for (r, d) in self.dirty.iter_mut().enumerate() {
            if *d > 0 {
                let o = r * s * dh;
                k[o..o + *d * dh].fill(0.0);
                v[o..o + *d * dh].fill(0.0);
                *d = 0;
            }
        }
        if let Data::I32(sl) = &mut self.seq_len.data {
            sl.fill(0);
        }
    }

    /// Mutable views `(k, v, seq_len, dirty)`; same dirty contract as
    /// [`SparseStaging::parts_mut`], extent per `(b, kv head)` row.
    pub fn parts_mut(
        &mut self,
    ) -> (&mut [f32], &mut [f32], &mut [i32], &mut [usize]) {
        let k = f32_mut(&mut self.k);
        let v = f32_mut(&mut self.v);
        let sl = match &mut self.seq_len.data {
            Data::I32(x) => x.as_mut_slice(),
            Data::F32(_) => unreachable!("seq_len is i32"),
        };
        (k, v, sl, &mut self.dirty[..])
    }
}

/// Engine-owned arena: one [`SparseStaging`] per `(heads, t_cap)` shape
/// ever requested (a handful — one per compiled staging variant), plus at
/// most one [`DenseStaging`]. Sets are created on first use and live for
/// the engine's lifetime.
#[derive(Default)]
pub struct StagingArena {
    sparse: HashMap<(usize, usize), SparseStaging>,
    dense: Option<DenseStaging>,
    allocations: usize,
}

impl StagingArena {
    pub fn new() -> StagingArena {
        StagingArena::default()
    }

    /// Buffer-set creations so far. Constant across steps once every
    /// staging variant has been seen — the zero-steady-state-allocation
    /// invariant the bench asserts.
    pub fn allocations(&self) -> usize {
        self.allocations
    }

    /// A dirty-cleared sparse set for `[b, heads, t_cap, dh]` staging.
    pub fn sparse(&mut self, b: usize, heads: usize, t_cap: usize,
                  dh: usize) -> &mut SparseStaging {
        let allocations = &mut self.allocations;
        let set = self.sparse.entry((heads, t_cap)).or_insert_with(|| {
            *allocations += 1;
            SparseStaging::new(b, heads, t_cap, dh)
        });
        debug_assert_eq!(set.k.shape, [b, heads, t_cap, dh]);
        set.reset();
        set
    }

    /// The dirty-cleared dense set for `[b, hkv, s, dh]` staging.
    pub fn dense(&mut self, b: usize, hkv: usize, s: usize,
                 dh: usize) -> &mut DenseStaging {
        let allocations = &mut self.allocations;
        let set = self.dense.get_or_insert_with(|| {
            *allocations += 1;
            DenseStaging::new(b, hkv, s, dh)
        });
        debug_assert_eq!(set.k.shape, [b, hkv, s, dh]);
        set.reset();
        set
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_reset_clears_only_dirty_rows_fully() {
        let mut arena = StagingArena::new();
        let (b, heads, t_cap, dh) = (2, 3, 8, 4);
        {
            let set = arena.sparse(b, heads, t_cap, dh);
            let (k, v, m, dirty) = set.parts_mut();
            // Write 5 tokens into row 1 and 2 tokens into row 4.
            for (row, n) in [(1usize, 5usize), (4, 2)] {
                let o = row * t_cap * dh;
                k[o..o + n * dh].fill(1.5);
                v[o..o + n * dh].fill(-2.5);
                m[row * t_cap..row * t_cap + n].fill(1.0);
                dirty[row] = n;
            }
        }
        // Re-acquire: everything must be zero again.
        let set = arena.sparse(b, heads, t_cap, dh);
        assert!(set.k.as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(set.v.as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(set.mask.as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert_eq!(arena.allocations(), 1);
    }

    #[test]
    fn distinct_shapes_get_distinct_sets_once() {
        let mut arena = StagingArena::new();
        arena.sparse(2, 2, 8, 4);
        arena.sparse(2, 4, 8, 4);
        arena.sparse(2, 2, 16, 4);
        arena.dense(2, 2, 32, 4);
        assert_eq!(arena.allocations(), 4);
        for _ in 0..10 {
            arena.sparse(2, 2, 8, 4);
            arena.sparse(2, 4, 8, 4);
            arena.sparse(2, 2, 16, 4);
            arena.dense(2, 2, 32, 4);
        }
        assert_eq!(arena.allocations(), 4, "steady state must not allocate sets");
    }

    #[test]
    fn dense_reset_zeroes_seq_len_and_extents() {
        let mut arena = StagingArena::new();
        {
            let set = arena.dense(2, 2, 16, 4);
            let (k, v, sl, dirty) = set.parts_mut();
            k[0..3 * 4].fill(9.0);
            v[0..3 * 4].fill(9.0);
            sl[0] = 3;
            dirty[0] = 3;
        }
        let set = arena.dense(2, 2, 16, 4);
        assert!(set.k.as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(set.v.as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(set.seq_len.as_i32().unwrap().iter().all(|&x| x == 0));
    }
}
