//! Persistent staging arenas for the decode hot path.
//!
//! The seed engine allocated and zero-filled fresh `k_sel`/`v_sel`/`mask`
//! (and, on the dense path, full-context `kc`/`vc`) staging buffers at
//! every `run_attention` call — for every layer of every decode token.
//! Those buffers are the largest host-side objects on the step, so the
//! allocator + memset dominated coordinator time and buried the paper's
//! I/O argument (cost should scale with the token *budget*).
//!
//! [`StagingArena`] owns one buffer set per staging shape for the
//! engine's lifetime. Each set tracks a *dirty extent* per `(batch,
//! head)` row — how many staged tokens the previous use wrote — and
//! `acquire` zeroes exactly those extents, restoring the all-zeros
//! invariant the executables expect while touching only bytes that were
//! actually written. Steady-state decode therefore performs zero heap
//! allocation in the gather stage, and clearing cost scales with the
//! selection budget, not the staging capacity.
//!
//! The arena is pure host code (no PJRT dependency), so the
//! `decode_hot_path` bench exercises it under the default feature set.

use std::collections::HashMap;

use crate::runtime::tensor::{Data, HostTensor};

/// One sparse staging shape: `k`/`v` are `[b, heads, t_cap, dh]`, `mask`
/// is `[b, heads, t_cap]`.
pub struct SparseStaging {
    pub k: HostTensor,
    pub v: HostTensor,
    pub mask: HostTensor,
    /// Tokens written per `(b, head)` row at the last use.
    dirty: Vec<usize>,
    t_cap: usize,
    dh: usize,
}

fn f32_mut(t: &mut HostTensor) -> &mut [f32] {
    match &mut t.data {
        Data::F32(v) => v.as_mut_slice(),
        Data::I32(_) => unreachable!("staging tensors are f32"),
    }
}

impl SparseStaging {
    fn new(b: usize, heads: usize, t_cap: usize, dh: usize) -> SparseStaging {
        SparseStaging {
            k: HostTensor::zeros_f32(vec![b, heads, t_cap, dh]),
            v: HostTensor::zeros_f32(vec![b, heads, t_cap, dh]),
            mask: HostTensor::zeros_f32(vec![b, heads, t_cap]),
            dirty: vec![0; b * heads],
            t_cap,
            dh,
        }
    }

    /// Zero the previously-written extents, restoring all-zeros.
    fn reset(&mut self) {
        let (t_cap, dh) = (self.t_cap, self.dh);
        let k = f32_mut(&mut self.k);
        let v = f32_mut(&mut self.v);
        let m = f32_mut(&mut self.mask);
        for (r, d) in self.dirty.iter_mut().enumerate() {
            if *d > 0 {
                let o = r * t_cap * dh;
                k[o..o + *d * dh].fill(0.0);
                v[o..o + *d * dh].fill(0.0);
                m[r * t_cap..r * t_cap + *d].fill(0.0);
                *d = 0;
            }
        }
    }

    /// Mutable views for the gather stage: `(k, v, mask, dirty)`. The
    /// caller must record, for every row it writes, the staged token
    /// count in `dirty[b * heads + row]` so the next acquire can clear
    /// it.
    pub fn parts_mut(
        &mut self,
    ) -> (&mut [f32], &mut [f32], &mut [f32], &mut [usize]) {
        let k = f32_mut(&mut self.k);
        let v = f32_mut(&mut self.v);
        let m = f32_mut(&mut self.mask);
        (k, v, m, &mut self.dirty[..])
    }

    /// Staged token counts per `(b, head)` row from the last gather
    /// (sparsity / I/O accounting reads these after the write pass).
    pub fn dirty(&self) -> &[usize] {
        &self.dirty
    }
}

/// Dense staging: `k`/`v` are `[b, hkv, s, dh]`, `seq_len` is `[b]` i32.
pub struct DenseStaging {
    pub k: HostTensor,
    pub v: HostTensor,
    pub seq_len: HostTensor,
    /// Tokens written per `(b, kv head)` row at the last use.
    dirty: Vec<usize>,
    s: usize,
    dh: usize,
}

impl DenseStaging {
    fn new(b: usize, hkv: usize, s: usize, dh: usize) -> DenseStaging {
        DenseStaging {
            k: HostTensor::zeros_f32(vec![b, hkv, s, dh]),
            v: HostTensor::zeros_f32(vec![b, hkv, s, dh]),
            seq_len: HostTensor::i32(vec![b], vec![0; b]),
            dirty: vec![0; b * hkv],
            s,
            dh,
        }
    }

    fn reset(&mut self) {
        let (s, dh) = (self.s, self.dh);
        let k = f32_mut(&mut self.k);
        let v = f32_mut(&mut self.v);
        for (r, d) in self.dirty.iter_mut().enumerate() {
            if *d > 0 {
                let o = r * s * dh;
                k[o..o + *d * dh].fill(0.0);
                v[o..o + *d * dh].fill(0.0);
                *d = 0;
            }
        }
        if let Data::I32(sl) = &mut self.seq_len.data {
            sl.fill(0);
        }
    }

    /// Mutable views `(k, v, seq_len, dirty)`; same dirty contract as
    /// [`SparseStaging::parts_mut`], extent per `(b, kv head)` row.
    pub fn parts_mut(
        &mut self,
    ) -> (&mut [f32], &mut [f32], &mut [i32], &mut [usize]) {
        let k = f32_mut(&mut self.k);
        let v = f32_mut(&mut self.v);
        let sl = match &mut self.seq_len.data {
            Data::I32(x) => x.as_mut_slice(),
            Data::F32(_) => unreachable!("seq_len is i32"),
        };
        (k, v, sl, &mut self.dirty[..])
    }

    /// Staged token counts per `(b, kv head)` row from the last gather.
    pub fn dirty(&self) -> &[usize] {
        &self.dirty
    }
}

/// Prefill staging: the padded `ids [b, s]` / `seq_len [b]` batch tensors
/// plus the per-token `krow`/`vrow`/`prow` scatter rows the prefill loop
/// copies layer outputs through. The seed engine allocated all five per
/// `admit_and_prefill` call; holding them here extends the decode path's
/// arena discipline to prefill — `ids` is dirty-extent cleared (only the
/// token spans written for the previously admitted slots), `seq_len` is
/// `[b]` and cleared whole, and the rows are plain reused scratch.
///
/// Chunked prefill (PR 7) adds a per-row resume `cursor`: a row whose
/// cursor is nonzero is mid-chunk — its staged token prefix must survive
/// the next acquire so the following chunk only writes the new span. A
/// cursor returns to zero when the slot's prefill completes (or via
/// [`PrefillStaging::abort_row`] when the slot is reaped/preempted
/// half-prefilled), after which the ordinary dirty-extent clear reclaims
/// the row. All bookkeeping lives in vectors sized at construction, so
/// the zero-steady-state-allocation invariant is untouched.
pub struct PrefillStaging {
    pub ids: HostTensor,     // [b, s] i32
    pub seq_len: HostTensor, // [b] i32
    krow: Vec<f32>,
    vrow: Vec<f32>,
    prow: Vec<f32>,
    /// Prompt tokens written per batch row at the last use.
    dirty: Vec<usize>,
    /// Tokens of row `i` already staged by an unfinished chunked prefill;
    /// `0` = row is free to clear on acquire.
    cursor: Vec<usize>,
    s: usize,
}

impl PrefillStaging {
    fn new(b: usize, s: usize, row_elems: usize) -> PrefillStaging {
        PrefillStaging {
            ids: HostTensor::i32(vec![b, s], vec![0; b * s]),
            seq_len: HostTensor::i32(vec![b], vec![0; b]),
            krow: vec![0.0; row_elems],
            vrow: vec![0.0; row_elems],
            prow: vec![0.0; row_elems],
            dirty: vec![0; b],
            cursor: vec![0; b],
            s,
        }
    }

    fn reset(&mut self) {
        let s = self.s;
        let ids = match &mut self.ids.data {
            Data::I32(x) => x.as_mut_slice(),
            Data::F32(_) => unreachable!("ids are i32"),
        };
        for (r, d) in self.dirty.iter_mut().enumerate() {
            // Mid-chunk rows keep their staged prefix across acquires.
            if *d > 0 && self.cursor[r] == 0 {
                ids[r * s..r * s + *d].fill(0);
                *d = 0;
            }
        }
        if let Data::I32(sl) = &mut self.seq_len.data {
            sl.fill(0);
        }
    }

    /// Mutable views `(ids, seq_len, dirty)`: the caller writes each
    /// admitted slot's prompt into `ids[i*s..]`, its length into
    /// `seq_len[i]`, and records the length in `dirty[i]` for the next
    /// acquire's clear.
    pub fn ids_mut(&mut self) -> (&mut [i32], &mut [i32], &mut [usize]) {
        let ids = match &mut self.ids.data {
            Data::I32(x) => x.as_mut_slice(),
            Data::F32(_) => unreachable!("ids are i32"),
        };
        let sl = match &mut self.seq_len.data {
            Data::I32(x) => x.as_mut_slice(),
            Data::F32(_) => unreachable!("seq_len is i32"),
        };
        (ids, sl, &mut self.dirty[..])
    }

    /// The `(krow, vrow, prow)` per-token scatter rows (`[hkv * dh]`
    /// each), overwritten for every token of the prefill scatter loop.
    pub fn rows_mut(&mut self) -> (&mut [f32], &mut [f32], &mut [f32]) {
        (&mut self.krow[..], &mut self.vrow[..], &mut self.prow[..])
    }

    /// Mutable views `(ids, seq_len, dirty, cursor)` for the chunked
    /// prefill loop: same contract as [`PrefillStaging::ids_mut`], plus
    /// the per-row resume cursor. A chunk writes tokens
    /// `[cursor[i], end)` into `ids[i*s..]`, advances `cursor[i] = end`
    /// (and `dirty[i] = end`), and zeroes `cursor[i]` once the slot's
    /// prefill completes so the next acquire clears the row.
    pub fn chunk_mut(
        &mut self,
    ) -> (&mut [i32], &mut [i32], &mut [usize], &mut [usize]) {
        let ids = match &mut self.ids.data {
            Data::I32(x) => x.as_mut_slice(),
            Data::F32(_) => unreachable!("ids are i32"),
        };
        let sl = match &mut self.seq_len.data {
            Data::I32(x) => x.as_mut_slice(),
            Data::F32(_) => unreachable!("seq_len is i32"),
        };
        (ids, sl, &mut self.dirty[..], &mut self.cursor[..])
    }

    /// Tokens row `i` has staged for an unfinished chunked prefill.
    pub fn cursor(&self, i: usize) -> usize {
        self.cursor[i]
    }

    /// Drop row `i`'s resume cursor (the slot was reaped or preempted
    /// half-prefilled); its staged span is reclaimed on the next acquire.
    pub fn abort_row(&mut self, i: usize) {
        self.cursor[i] = 0;
    }
}

/// Engine-owned arena: one [`SparseStaging`] per `(heads, t_cap)` shape
/// ever requested (a handful — one per compiled staging variant), plus at
/// most one [`DenseStaging`]. Sets are created on first use and live for
/// the engine's lifetime.
#[derive(Default)]
pub struct StagingArena {
    sparse: HashMap<(usize, usize), SparseStaging>,
    dense: Option<DenseStaging>,
    prefill: Option<PrefillStaging>,
    allocations: usize,
}

impl StagingArena {
    pub fn new() -> StagingArena {
        StagingArena::default()
    }

    /// Buffer-set creations so far. Constant across steps once every
    /// staging variant has been seen — the zero-steady-state-allocation
    /// invariant the bench asserts.
    pub fn allocations(&self) -> usize {
        self.allocations
    }

    /// A dirty-cleared sparse set for `[b, heads, t_cap, dh]` staging.
    pub fn sparse(&mut self, b: usize, heads: usize, t_cap: usize,
                  dh: usize) -> &mut SparseStaging {
        let allocations = &mut self.allocations;
        let set = self.sparse.entry((heads, t_cap)).or_insert_with(|| {
            *allocations += 1;
            SparseStaging::new(b, heads, t_cap, dh)
        });
        debug_assert_eq!(set.k.shape, [b, heads, t_cap, dh]);
        set.reset();
        set
    }

    /// The dirty-cleared dense set for `[b, hkv, s, dh]` staging.
    pub fn dense(&mut self, b: usize, hkv: usize, s: usize,
                 dh: usize) -> &mut DenseStaging {
        let allocations = &mut self.allocations;
        let set = self.dense.get_or_insert_with(|| {
            *allocations += 1;
            DenseStaging::new(b, hkv, s, dh)
        });
        debug_assert_eq!(set.k.shape, [b, hkv, s, dh]);
        set.reset();
        set
    }

    /// Read access to a staged sparse set *without* acquiring (no
    /// dirty-extent reset) — post-gather inspection for tests/benches.
    pub fn sparse_peek(&self, heads: usize, t_cap: usize) -> Option<&SparseStaging> {
        self.sparse.get(&(heads, t_cap))
    }

    /// Read access to the staged dense set without acquiring.
    pub fn dense_peek(&self) -> Option<&DenseStaging> {
        self.dense.as_ref()
    }

    /// The dirty-cleared prefill set (`ids [b, s]`, `seq_len [b]`, and
    /// `row_elems`-long scatter rows).
    pub fn prefill(&mut self, b: usize, s: usize,
                   row_elems: usize) -> &mut PrefillStaging {
        let allocations = &mut self.allocations;
        let set = self.prefill.get_or_insert_with(|| {
            *allocations += 1;
            PrefillStaging::new(b, s, row_elems)
        });
        debug_assert_eq!(set.ids.shape, [b, s]);
        debug_assert_eq!(set.krow.len(), row_elems);
        set.reset();
        set
    }

    /// Drop prefill row `i`'s chunk-resume cursor without acquiring (the
    /// owning slot was reaped or preempted half-prefilled). No-op before
    /// the first prefill acquire.
    pub fn abort_prefill_row(&mut self, i: usize) {
        if let Some(set) = self.prefill.as_mut() {
            set.abort_row(i);
        }
    }

    /// Read access to the staged prefill set without acquiring.
    pub fn prefill_peek(&self) -> Option<&PrefillStaging> {
        self.prefill.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_reset_clears_only_dirty_rows_fully() {
        let mut arena = StagingArena::new();
        let (b, heads, t_cap, dh) = (2, 3, 8, 4);
        {
            let set = arena.sparse(b, heads, t_cap, dh);
            let (k, v, m, dirty) = set.parts_mut();
            // Write 5 tokens into row 1 and 2 tokens into row 4.
            for (row, n) in [(1usize, 5usize), (4, 2)] {
                let o = row * t_cap * dh;
                k[o..o + n * dh].fill(1.5);
                v[o..o + n * dh].fill(-2.5);
                m[row * t_cap..row * t_cap + n].fill(1.0);
                dirty[row] = n;
            }
        }
        // Re-acquire: everything must be zero again.
        let set = arena.sparse(b, heads, t_cap, dh);
        assert!(set.k.as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(set.v.as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(set.mask.as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert_eq!(arena.allocations(), 1);
    }

    #[test]
    fn distinct_shapes_get_distinct_sets_once() {
        let mut arena = StagingArena::new();
        arena.sparse(2, 2, 8, 4);
        arena.sparse(2, 4, 8, 4);
        arena.sparse(2, 2, 16, 4);
        arena.dense(2, 2, 32, 4);
        assert_eq!(arena.allocations(), 4);
        for _ in 0..10 {
            arena.sparse(2, 2, 8, 4);
            arena.sparse(2, 4, 8, 4);
            arena.sparse(2, 2, 16, 4);
            arena.dense(2, 2, 32, 4);
        }
        assert_eq!(arena.allocations(), 4, "steady state must not allocate sets");
    }

    #[test]
    fn prefill_reset_clears_only_written_spans() {
        let mut arena = StagingArena::new();
        let (b, s, row) = (3, 16, 8);
        {
            let set = arena.prefill(b, s, row);
            let (ids, sl, dirty) = set.ids_mut();
            // Admit prompts into rows 0 and 2.
            for (r, plen) in [(0usize, 5usize), (2, 9)] {
                for t in 0..plen {
                    ids[r * s + t] = (100 + t) as i32;
                }
                sl[r] = plen as i32;
                dirty[r] = plen;
            }
            let (kr, vr, pr) = set.rows_mut();
            kr.fill(1.0);
            vr.fill(2.0);
            pr.fill(3.0);
        }
        // Re-acquire: ids and seq_len must be all zero again.
        let set = arena.prefill(b, s, row);
        assert!(set.ids.as_i32().unwrap().iter().all(|&x| x == 0));
        assert!(set.seq_len.as_i32().unwrap().iter().all(|&x| x == 0));
        assert_eq!(arena.allocations(), 1);
        // Steady state: many acquires, still one buffer set.
        for _ in 0..10 {
            arena.prefill(b, s, row);
        }
        assert_eq!(arena.allocations(), 1);
    }

    #[test]
    fn prefill_cursor_keeps_row_staged_across_acquires() {
        let mut arena = StagingArena::new();
        let (b, s, row) = (2, 16, 8);
        {
            let set = arena.prefill(b, s, row);
            let (ids, sl, dirty, cursor) = set.chunk_mut();
            // Row 0: first chunk of a long prompt (4 of 10 tokens).
            for t in 0..4 {
                ids[t] = (50 + t) as i32;
            }
            sl[0] = 4;
            dirty[0] = 4;
            cursor[0] = 4;
            // Row 1: a complete one-shot prefill.
            ids[s] = 7;
            sl[1] = 1;
            dirty[1] = 1;
        }
        {
            // Re-acquire: row 0's staged prefix survives, row 1 cleared.
            let set = arena.prefill(b, s, row);
            let kept: Vec<i32> = set.ids.as_i32().unwrap()[..4].to_vec();
            assert_eq!(kept, vec![50, 51, 52, 53], "mid-chunk span must persist");
            assert_eq!(set.ids.as_i32().unwrap()[s], 0, "finished row cleared");
            assert!(set.seq_len.as_i32().unwrap().iter().all(|&x| x == 0));
            assert_eq!(set.cursor(0), 4);
            // Second chunk completes the row.
            let (ids, sl, dirty, cursor) = set.chunk_mut();
            for t in 4..10 {
                ids[t] = (50 + t) as i32;
            }
            sl[0] = 10;
            dirty[0] = 10;
            cursor[0] = 0;
        }
        // Completed: the next acquire clears the whole staged span.
        let set = arena.prefill(b, s, row);
        assert!(set.ids.as_i32().unwrap().iter().all(|&x| x == 0));
        assert_eq!(arena.allocations(), 1, "chunking must not allocate sets");
    }

    #[test]
    fn abort_prefill_row_releases_a_mid_chunk_span() {
        let mut arena = StagingArena::new();
        let (b, s, row) = (1, 8, 4);
        {
            let set = arena.prefill(b, s, row);
            let (ids, sl, dirty, cursor) = set.chunk_mut();
            ids[0] = 9;
            ids[1] = 9;
            sl[0] = 2;
            dirty[0] = 2;
            cursor[0] = 2;
        }
        // Cancelled mid-prefill: the engine aborts the row...
        arena.abort_prefill_row(0);
        assert_eq!(arena.prefill_peek().unwrap().cursor(0), 0);
        // ...and the next acquire reclaims it.
        let set = arena.prefill(b, s, row);
        assert!(set.ids.as_i32().unwrap().iter().all(|&x| x == 0));
    }

    #[test]
    fn dirty_accessors_report_last_extents() {
        let mut arena = StagingArena::new();
        {
            let set = arena.sparse(1, 2, 8, 4);
            let (_, _, _, dirty) = set.parts_mut();
            dirty[0] = 3;
            dirty[1] = 7;
        }
        // Still readable without re-acquiring (which would clear them).
        let set = arena.sparse_peek(2, 8).unwrap();
        assert_eq!(set.dirty(), &[3, 7]);
    }

    #[test]
    fn dense_reset_zeroes_seq_len_and_extents() {
        let mut arena = StagingArena::new();
        {
            let set = arena.dense(2, 2, 16, 4);
            let (k, v, sl, dirty) = set.parts_mut();
            k[0..3 * 4].fill(9.0);
            v[0..3 * 4].fill(9.0);
            sl[0] = 3;
            dirty[0] = 3;
        }
        let set = arena.dense(2, 2, 16, 4);
        assert!(set.k.as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(set.v.as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(set.seq_len.as_i32().unwrap().iter().all(|&x| x == 0));
    }
}
