//! Per-shard KV memory planning.
//!
//! Admission today is gated only by a *count* (`batch + queue_depth`
//! requests per shard), which says nothing about whether those requests
//! *fit in KV memory*: a burst of long prompts can be admitted and then
//! starve each other's pages mid-decode. This module makes the
//! `queue_depth` knob principled by planning in the same unit the
//! engines allocate in — pages.
//!
//! Two pieces:
//!
//! - [`PageGeometry`] — how an engine's page pool maps request shapes to
//!   pages. Reported once per shard at startup (in the `Ready` event) so
//!   the router can project a request's **peak** page demand (prompt +
//!   `max_new`, page-rounded) without asking the engine.
//! - [`MemoryPlan`] — an atomic ledger of pages the router has promised
//!   to requests routed to a shard (admitted *or* still queued). A
//!   request reserves its projected peak at submit and releases it when
//!   its completion is observed, so the plan bounds *future* demand, not
//!   just current usage. When a reservation would overflow the shard's
//!   budget on every shard, the router answers `Deferred` (retry later —
//!   memory, not compute, is the bottleneck) instead of `Rejected`.
//!
//! The plan is deliberately conservative (peak projection assumes every
//! request decodes to `max_new`) and deliberately over-committed (the
//! budget covers the queue as well as the pool, since queued requests
//! only need their pages once a slot frees). Mid-decode shortfalls that
//! slip through — or are injected by the fault harness — are handled by
//! preemption in the engines, not here.

use std::sync::atomic::{AtomicUsize, Ordering};

/// How an engine's KV page pool maps request shapes to page counts.
/// `Default` (all zeros) means "no page accounting": the plan stays
/// disabled and admission falls back to pure count gating.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PageGeometry {
    /// Total pages in the engine's pool.
    pub pool_pages: usize,
    /// Tokens covered by one page row (0 = token count does not matter;
    /// use `fixed_pages_per_seq`).
    pub tokens_per_page: usize,
    /// Page rows allocated per sequence per token-page (e.g. one per
    /// layer for the PJRT engine). Treated as 1 when 0.
    pub rows_per_seq: usize,
    /// Flat per-sequence page cost for engines whose allocation does not
    /// depend on sequence length (the sim's legacy `pages_per_slot`
    /// model). Takes precedence over the token model when non-zero.
    pub fixed_pages_per_seq: usize,
    /// Concurrent batch slots the pool serves.
    pub slots: usize,
}

impl PageGeometry {
    /// Projected peak pages for a request: its full KV footprint if it
    /// decodes all the way to `max_new` (+1 for the trailing token whose
    /// KV lands after the stop decision), page-rounded.
    pub fn project(&self, prompt_len: usize, max_new: usize) -> usize {
        if self.fixed_pages_per_seq > 0 {
            return self.fixed_pages_per_seq;
        }
        if self.tokens_per_page == 0 {
            return 0;
        }
        let tokens = prompt_len + max_new + 1;
        tokens.div_ceil(self.tokens_per_page) * self.rows_per_seq.max(1)
    }

    /// Pages already resident for `blocks` cached prefix blocks under
    /// the token paging model — the prefix-cache discount: a request
    /// whose leading blocks are warm on a shard maps those pages instead
    /// of allocating them, so the router charges shared pages once
    /// (reservation = projected peak − discount). Zero under the fixed
    /// model (its per-sequence cost is length-independent) and on
    /// engines with no page accounting. Advisory like the rest of the
    /// plan: an over-discount is absorbed by engine preemption.
    pub fn prefix_discount(&self, blocks: usize) -> usize {
        if self.fixed_pages_per_seq > 0 || self.tokens_per_page == 0 {
            return 0;
        }
        blocks * self.rows_per_seq.max(1)
    }

    /// Page budget the router may promise against this shard: the pool
    /// itself plus one average-sequence share per overflow-queue slot
    /// (queued requests need their pages only once a batch slot frees,
    /// so a full pool with a full queue is an intended 1x+queue
    /// overcommit — *unbounded* overcommit is what the plan prevents).
    pub fn budget(&self, queue_depth: usize) -> usize {
        if self.pool_pages == 0 {
            return 0;
        }
        let share = if self.fixed_pages_per_seq > 0 {
            self.fixed_pages_per_seq
        } else {
            self.pool_pages.div_ceil(self.slots.max(1))
        };
        self.pool_pages + queue_depth * share
    }
}

/// Atomic ledger of pages promised to one shard. Created disabled
/// (budget 0) and armed by the router once the shard reports its
/// [`PageGeometry`]; a disabled plan admits everything, preserving
/// pre-memory-planning behaviour for engines that report no geometry.
#[derive(Debug, Default)]
pub struct MemoryPlan {
    budget: AtomicUsize,
    planned: AtomicUsize,
    peak: AtomicUsize,
}

impl MemoryPlan {
    pub fn set_budget(&self, budget: usize) {
        self.budget.store(budget, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.budget.load(Ordering::Relaxed) > 0
    }

    pub fn budget(&self) -> usize {
        self.budget.load(Ordering::Relaxed)
    }

    pub fn planned(&self) -> usize {
        self.planned.load(Ordering::Relaxed)
    }

    /// High-water mark of `planned` (pages promised at once).
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Would `pages` more fit under the budget right now? (Advisory —
    /// racy by design; the authoritative check is `try_reserve`.)
    pub fn fits(&self, pages: usize) -> bool {
        !self.enabled()
            || self.planned.load(Ordering::Relaxed) + pages
                <= self.budget.load(Ordering::Relaxed)
    }

    /// Reserve `pages`, failing (and rolling back) if that would exceed
    /// the budget. Always succeeds on a disabled plan.
    pub fn try_reserve(&self, pages: usize) -> bool {
        if !self.enabled() || pages == 0 {
            return true;
        }
        let prev = self.planned.fetch_add(pages, Ordering::Relaxed);
        if prev + pages > self.budget.load(Ordering::Relaxed) {
            self.planned.fetch_sub(pages, Ordering::Relaxed);
            return false;
        }
        self.peak.fetch_max(prev + pages, Ordering::Relaxed);
        true
    }

    /// Reserve without a budget check — used when a reservation is
    /// *transferred* from another shard (work stealing moves the request
    /// whether or not the thief's plan has headroom; the thief chose to
    /// take the work).
    pub fn force_reserve(&self, pages: usize) {
        if !self.enabled() || pages == 0 {
            return;
        }
        let prev = self.planned.fetch_add(pages, Ordering::Relaxed);
        self.peak.fetch_max(prev + pages, Ordering::Relaxed);
    }

    /// Release a reservation (on completion, cancellation, or transfer).
    /// Saturates at zero so a release racing a budget re-arm can't
    /// underflow.
    pub fn release(&self, pages: usize) {
        if pages == 0 {
            return;
        }
        let _ = self.planned.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |p| Some(p.saturating_sub(pages)),
        );
    }

    /// Zero the ledger and return what it still held — shard-supervisor
    /// reconciliation after a crash. Per-request reservations the router
    /// rescues are released (or transferred) individually first; anything
    /// left after that is state only the dead shard knew about, and a
    /// respawned engine starts from an empty pool, so the plan must too.
    pub fn reclaim(&self) -> usize {
        self.planned.swap(0, Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_model_projection_rounds_up_pages() {
        let g = PageGeometry {
            pool_pages: 64,
            tokens_per_page: 16,
            rows_per_seq: 2,
            fixed_pages_per_seq: 0,
            slots: 4,
        };
        // 10 prompt + 5 new + 1 trailing = 16 tokens = 1 page * 2 rows.
        assert_eq!(g.project(10, 5), 2);
        // 17 tokens -> 2 pages * 2 rows.
        assert_eq!(g.project(10, 6), 4);
        assert_eq!(g.project(0, 0), 2, "even an empty request costs a page");
    }

    #[test]
    fn fixed_model_projection_ignores_lengths() {
        let g = PageGeometry {
            pool_pages: 16,
            tokens_per_page: 0,
            rows_per_seq: 0,
            fixed_pages_per_seq: 4,
            slots: 4,
        };
        assert_eq!(g.project(1, 1), 4);
        assert_eq!(g.project(500, 100), 4);
    }

    #[test]
    fn prefix_discount_only_applies_to_the_token_model() {
        let tokens = PageGeometry {
            pool_pages: 64,
            tokens_per_page: 16,
            rows_per_seq: 2,
            fixed_pages_per_seq: 0,
            slots: 4,
        };
        assert_eq!(tokens.prefix_discount(3), 6, "blocks * rows_per_seq");
        // Discounted reservation never goes negative even if the cached
        // prefix covers the whole projection.
        let need = tokens.project(10, 5).saturating_sub(tokens.prefix_discount(10));
        assert_eq!(need, 0);
        let fixed = PageGeometry {
            pool_pages: 16,
            fixed_pages_per_seq: 4,
            slots: 4,
            ..Default::default()
        };
        assert_eq!(fixed.prefix_discount(3), 0);
        assert_eq!(PageGeometry::default().prefix_discount(3), 0);
    }

    #[test]
    fn budget_adds_one_share_per_queue_slot() {
        let fixed = PageGeometry {
            pool_pages: 16,
            fixed_pages_per_seq: 4,
            slots: 4,
            ..Default::default()
        };
        assert_eq!(fixed.budget(0), 16);
        assert_eq!(fixed.budget(2), 24);
        let tokens = PageGeometry {
            pool_pages: 10,
            tokens_per_page: 8,
            rows_per_seq: 1,
            fixed_pages_per_seq: 0,
            slots: 4,
        };
        // share = ceil(10/4) = 3.
        assert_eq!(tokens.budget(2), 16);
        assert_eq!(PageGeometry::default().budget(32), 0, "no pool, no budget");
    }

    #[test]
    fn disabled_plan_admits_everything() {
        let p = MemoryPlan::default();
        assert!(!p.enabled());
        assert!(p.fits(usize::MAX / 2));
        assert!(p.try_reserve(1_000_000));
        assert_eq!(p.planned(), 0, "disabled plan keeps no ledger");
    }

    #[test]
    fn reserve_release_tracks_budget_and_peak() {
        let p = MemoryPlan::default();
        p.set_budget(10);
        assert!(p.enabled());
        assert!(p.try_reserve(6));
        assert!(p.try_reserve(4));
        assert_eq!(p.planned(), 10);
        assert!(!p.try_reserve(1), "budget exhausted");
        assert_eq!(p.planned(), 10, "failed reserve rolls back");
        p.release(4);
        assert_eq!(p.planned(), 6);
        assert!(p.try_reserve(3));
        assert_eq!(p.peak(), 10, "peak survives releases");
        p.release(100);
        assert_eq!(p.planned(), 0, "release saturates at zero");
    }

    #[test]
    fn reclaim_zeroes_the_ledger_and_reports_the_leak() {
        let p = MemoryPlan::default();
        p.set_budget(10);
        assert!(p.try_reserve(7));
        assert_eq!(p.reclaim(), 7, "reclaim returns what was still planned");
        assert_eq!(p.planned(), 0);
        assert!(p.try_reserve(10), "budget is whole again after reclaim");
        p.release(10);
        assert_eq!(p.reclaim(), 0, "clean ledger reclaims nothing");
        assert_eq!(p.peak(), 10, "reclaim never rewrites history");
    }

    #[test]
    fn force_reserve_ignores_budget_but_moves_peak() {
        let p = MemoryPlan::default();
        p.set_budget(4);
        assert!(p.try_reserve(4));
        p.force_reserve(3);
        assert_eq!(p.planned(), 7, "transfers land even over budget");
        assert_eq!(p.peak(), 7);
        assert!(!p.try_reserve(1));
        p.release(7);
        assert!(p.try_reserve(4));
    }
}
