//! Trace-driven scheduling: replays a timed arrival trace against the
//! engine (continuous batching happens inside `Engine::step`), used by
//! the serving benchmark. Arrivals can be replayed in real time or in
//! virtual time (as fast as the engine can go, arrival order preserved).

use std::time::Instant;

use anyhow::Result;

use super::engine::Engine;
use super::request::{Completion, Request};
use crate::workload::trace::TracedRequest;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Replay {
    /// Honour wall-clock arrival times (sleeps while idle).
    RealTime,
    /// Submit each request as soon as the engine has consumed everything
    /// that arrived earlier (throughput-oriented).
    Virtual,
}

pub struct TraceRunner {
    pub replay: Replay,
}

impl TraceRunner {
    pub fn run(&self, engine: &mut Engine, trace: &[TracedRequest])
               -> Result<Vec<Completion>> {
        let mut completions = Vec::new();
        let start = Instant::now();
        let mut next = 0usize;
        let mut id = 0u64;
        while next < trace.len() || !engine.idle() {
            // Admit everything whose arrival time has passed.
            while next < trace.len() {
                let due = match self.replay {
                    Replay::RealTime => {
                        start.elapsed().as_secs_f64() >= trace[next].arrival_s
                    }
                    Replay::Virtual => true,
                };
                if !due {
                    break;
                }
                let t = &trace[next];
                engine.submit(Request {
                    id,
                    prompt: t.episode.prompt.clone(),
                    max_new: t.max_new,
                });
                id += 1;
                next += 1;
                // In virtual mode admit at most one burst per step so the
                // queue still exercises batching decisions.
                if self.replay == Replay::Virtual && engine.pending() >= engine.batch_size()
                {
                    break;
                }
            }
            if engine.idle() {
                // Real-time replay with nothing due yet: wait briefly.
                std::thread::sleep(std::time::Duration::from_millis(1));
                continue;
            }
            completions.extend(engine.step()?);
        }
        Ok(completions)
    }
}
