//! Trace-driven scheduling: replays a timed arrival trace against a
//! decode engine or a sharded [`EngineGroup`] (continuous batching
//! happens inside the engines), used by the serving benchmark and the
//! end-to-end serving tests. Arrivals can be replayed in real time or in
//! virtual time (as fast as the fleet can go, arrival order preserved).
//!
//! Requests are numbered `0..n` in arrival order in both modes, so runs
//! over the same trace are comparable per-request across replay modes,
//! shard counts, and engine implementations.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::request::{Completion, Request, StopReason};
use super::shard::{EngineGroup, SubmitOutcome};
use super::DecodeEngine;
use crate::workload::trace::TracedRequest;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Replay {
    /// Honour wall-clock arrival times (sleeps while idle).
    RealTime,
    /// Submit each request as soon as the fleet has admission headroom
    /// (throughput-oriented; arrival order preserved).
    Virtual,
}

pub struct TraceRunner {
    pub replay: Replay,
    /// Per-request deadline applied at submission (`None` = unbounded):
    /// each request must finish within this much time of entering the
    /// fleet or it is stopped with `StopReason::DeadlineExceeded` and
    /// returns its partial generation. Lets overload replays bound
    /// tail latency the way a deadline-aware client would.
    pub deadline: Option<Duration>,
    /// Consecutive failed submissions (`Rejected` or `Deferred`) one
    /// trace entry tolerates before the runner stops retrying it and
    /// synthesizes a `StopReason::ResourceExhausted` completion (empty
    /// generation, client-side wait as its e2e). `None` — the historical
    /// behaviour — retries forever, which livelocks the replay when the
    /// fleet can never admit the entry again (e.g. every shard dark
    /// after exhausting its restart budget). The backoff between
    /// attempts is exponential, so a cap of `k` spans roughly
    /// `2^min(k,6)` base intervals of client patience.
    pub give_up_after: Option<u32>,
    /// Trace entries abandoned under `give_up_after`, across every run
    /// driven through this runner. Atomic so the `&self` run methods
    /// can count. Public (external callers build `TraceRunner` with
    /// struct-update syntax, which needs every field visible); read it
    /// through [`TraceRunner::gave_up`].
    pub gave_up: AtomicU64,
}

impl Default for TraceRunner {
    fn default() -> Self {
        TraceRunner { replay: Replay::Virtual, deadline: None,
                      give_up_after: None, gave_up: AtomicU64::new(0) }
    }
}

impl TraceRunner {
    /// Entries abandoned after [`TraceRunner::give_up_after`] consecutive
    /// failed submissions, summed over every run on this runner.
    pub fn gave_up(&self) -> u64 {
        self.gave_up.load(Ordering::Relaxed)
    }

    /// Has entry `e` burned its retry budget? (`streak` counts
    /// consecutive `Rejected`/`Deferred` answers; a `Routed` resets it.)
    fn exhausted(&self, streak: u32) -> bool {
        self.give_up_after.map(|cap| streak >= cap).unwrap_or(false)
    }

    /// The structured outcome of abandoning entry `e`: the same
    /// `ResourceExhausted` completion an admission-starved request
    /// inside the fleet would produce, with nothing generated and the
    /// client-side wait (submission attempts + backoff) as its e2e — so
    /// summaries count the give-up instead of silently losing the entry.
    fn give_up_completion(&self, e: usize, t: &TracedRequest,
                          start: Instant) -> Completion {
        self.gave_up.fetch_add(1, Ordering::Relaxed);
        Completion {
            id: e as u64,
            prompt_len: t.episode.prompt.len(),
            generated: Vec::new(),
            stop: StopReason::ResourceExhausted,
            ttft: Duration::ZERO,
            e2e: start.elapsed(),
            stats: Default::default(),
        }
    }
    fn request(&self, id: u64, t: &TracedRequest) -> Request {
        let mut req = Request::new(id, t.episode.prompt.clone(), t.max_new);
        if let Some(d) = self.deadline {
            req.deadline = Some(Instant::now() + d);
        }
        req
    }

    /// Replay against a single engine on the caller's thread (the
    /// pre-sharding behaviour; equivalent to a 1-shard group).
    pub fn run<E: DecodeEngine>(&self, engine: &mut E, trace: &[TracedRequest])
                                -> Result<Vec<Completion>> {
        let mut completions = Vec::new();
        let start = Instant::now();
        let mut next = 0usize;
        let mut id = 0u64;
        // Same up-front guard as run_group: a clean error beats the
        // engine's submit assert.
        let max_prompt = engine.max_prompt_len();
        if let Some(t) = trace.iter().find(|t| t.episode.prompt.len() > max_prompt)
        {
            anyhow::bail!("trace prompt of {} tokens exceeds the engine's \
                           max prompt length {max_prompt}",
                          t.episode.prompt.len());
        }
        while next < trace.len() || !engine.idle() {
            // Admit everything whose arrival time has passed.
            while next < trace.len() {
                let due = match self.replay {
                    Replay::RealTime => {
                        start.elapsed().as_secs_f64() >= trace[next].arrival_s
                    }
                    Replay::Virtual => true,
                };
                if !due {
                    break;
                }
                engine.submit(self.request(id, &trace[next]));
                id += 1;
                next += 1;
                // In virtual mode admit at most one burst per step so the
                // queue still exercises batching decisions.
                if self.replay == Replay::Virtual
                    && engine.pending() >= engine.batch_size()
                {
                    break;
                }
            }
            if engine.idle() {
                // Real-time replay with nothing due yet: wait briefly.
                std::thread::sleep(Duration::from_millis(1));
                continue;
            }
            completions.extend(engine.step()?);
        }
        Ok(completions)
    }

    /// Replay against a sharded [`EngineGroup`]: the router dispatches
    /// admitted requests, shards decode concurrently, and completions
    /// fan back in. A 1-shard group reproduces `run`'s per-request
    /// output exactly (content-deterministic engines), which the serving
    /// tests assert. Admission backpressure is handled as a well-behaved
    /// client would: hold the request and retry with jittered
    /// exponential backoff — [`SubmitOutcome::Deferred`] seeds the
    /// backoff with the router's `retry_after_ms` hint,
    /// [`SubmitOutcome::Rejected`] with a short fixed base — so no trace
    /// entry is lost and the router is not hammered while saturated.
    /// Backoff is per entry: one deferred arrival waits out *its own*
    /// retry window while the walk skips ahead to other due entries, so
    /// a single stuck request never serializes the whole client. Ids
    /// stay equal to trace position in both replay modes (the module's
    /// comparability contract) regardless of the order submissions
    /// actually land in.
    pub fn run_group<E: DecodeEngine>(&self, group: &mut EngineGroup<E>,
                                      trace: &[TracedRequest])
                                      -> Result<Vec<Completion>> {
        let mut completions = Vec::with_capacity(trace.len());
        let start = Instant::now();
        let window = group.admission_window();
        // Client-side backoff state, one slot per trace entry. The RNG
        // seed is fixed: jitter decorrelates retries *within* a run, and
        // runs stay reproducible.
        let mut rng = crate::util::rng::Rng::new(0xBAC0_FF5E);
        let mut pending: Vec<usize> = (0..trace.len()).collect();
        let mut retry_at: Vec<Option<Instant>> = vec![None; trace.len()];
        let mut streak: Vec<u32> = vec![0; trace.len()];
        let mut backoff = |base_ms: u64, streak: &mut u32,
                           rng: &mut crate::util::rng::Rng| {
            let exp = 1u64 << (*streak).min(6);
            let wait_ms = (base_ms.max(1) * exp) as f64 * (0.5 + rng.f64());
            *streak += 1;
            Instant::now() + Duration::from_micros((wait_ms * 1000.0) as u64)
        };
        // Fail on the caller's thread with a clear message instead of
        // assert-panicking inside a shard (which would only surface as
        // "shard exited with requests in flight").
        let max_prompt = group.max_prompt_len();
        if let Some(t) = trace.iter().find(|t| t.episode.prompt.len() > max_prompt)
        {
            anyhow::bail!("trace prompt of {} tokens exceeds the engines' \
                           max prompt length {max_prompt}",
                          t.episode.prompt.len());
        }
        while !pending.is_empty() || group.inflight() > 0 {
            let mut i = 0;
            while i < pending.len() {
                let e = pending[i];
                // Inside this entry's backoff window: leave it for a
                // later pass, but keep walking — completions landing
                // meanwhile free capacity for the *other* due entries,
                // which must not wait behind this one's retry_at.
                if let Some(t) = retry_at[e] {
                    if Instant::now() < t {
                        i += 1;
                        continue;
                    }
                    retry_at[e] = None;
                }
                let due = match self.replay {
                    Replay::RealTime => {
                        start.elapsed().as_secs_f64() >= trace[e].arrival_s
                    }
                    // Keep a bounded backlog so shard queues stay warm
                    // without submitting the whole trace up front.
                    Replay::Virtual => group.inflight() < window,
                };
                if !due {
                    // Arrival times are non-decreasing and the virtual
                    // window gates globally, so no later entry is due
                    // either.
                    break;
                }
                match group.submit(self.request(e as u64, &trace[e]))? {
                    SubmitOutcome::Routed(_) => {
                        streak[e] = 0;
                        pending.remove(i); // successor shifts into i
                    }
                    // Memory headroom, not compute, is what's missing on
                    // the shard the router picked: honour its retry hint
                    // (with jitter and an escalating multiplier for
                    // repeat deferrals) for this entry, and move on — a
                    // differently-sized entry may still be routable.
                    SubmitOutcome::Deferred { retry_after_ms } => {
                        if self.exhausted(streak[e]) {
                            completions.push(
                                self.give_up_completion(e, &trace[e], start));
                            pending.remove(i);
                            continue;
                        }
                        retry_at[e] = Some(backoff(retry_after_ms,
                                                   &mut streak[e], &mut rng));
                        i += 1;
                    }
                    // Every shard is at capacity: any other entry would
                    // hear the same answer this instant, so stop the
                    // walk, poll below, retry after a short backoff
                    // (capacity frees as completions land, so this
                    // cannot livelock — unless the fleet can never
                    // admit again, which is what `give_up_after` bounds).
                    SubmitOutcome::Rejected => {
                        if self.exhausted(streak[e]) {
                            completions.push(
                                self.give_up_completion(e, &trace[e], start));
                            pending.remove(i);
                            continue;
                        }
                        retry_at[e] = Some(backoff(2, &mut streak[e],
                                                   &mut rng));
                        break;
                    }
                }
            }
            if let Some(c) = group.poll(Duration::from_millis(1))? {
                completions.push(c);
            }
        }
        Ok(completions)
    }

    /// Replay against the lane views of a multi-lane group (see
    /// [`EngineGroup::into_lanes`]) from a single client thread, the way
    /// the multi-reactor server partitions connections: trace entry `e`
    /// is submitted through lane `e % lanes` with `id == e`, which
    /// satisfies the lane-ownership contract (`id % lanes == lane`) by
    /// construction and keeps ids equal to trace position — the module's
    /// comparability contract — so a run over `L` lanes is comparable
    /// per-request with `run_group` over one. Admission windowing,
    /// deferral backoff, and rejection backoff are identical to
    /// `run_group` (same fixed RNG seed), with "inflight" meaning the sum
    /// across lanes. Polling rotates: each pass drains one lane with a
    /// short wait and the rest without blocking, so no lane's completions
    /// can starve behind another's.
    pub fn run_lanes<E: DecodeEngine>(&self, lanes: &mut [EngineGroup<E>],
                                      trace: &[TracedRequest])
                                      -> Result<Vec<Completion>> {
        let n_lanes = lanes.len();
        anyhow::ensure!(n_lanes > 0, "run_lanes needs at least one lane");
        if n_lanes == 1 {
            return self.run_group(&mut lanes[0], trace);
        }
        let mut completions = Vec::with_capacity(trace.len());
        let start = Instant::now();
        let window = lanes[0].admission_window();
        let mut rng = crate::util::rng::Rng::new(0xBAC0_FF5E);
        let mut pending: Vec<usize> = (0..trace.len()).collect();
        let mut retry_at: Vec<Option<Instant>> = vec![None; trace.len()];
        let mut streak: Vec<u32> = vec![0; trace.len()];
        let mut backoff = |base_ms: u64, streak: &mut u32,
                           rng: &mut crate::util::rng::Rng| {
            let exp = 1u64 << (*streak).min(6);
            let wait_ms = (base_ms.max(1) * exp) as f64 * (0.5 + rng.f64());
            *streak += 1;
            Instant::now() + Duration::from_micros((wait_ms * 1000.0) as u64)
        };
        let max_prompt = lanes[0].max_prompt_len();
        if let Some(t) = trace.iter().find(|t| t.episode.prompt.len() > max_prompt)
        {
            anyhow::bail!("trace prompt of {} tokens exceeds the engines' \
                           max prompt length {max_prompt}",
                          t.episode.prompt.len());
        }
        let inflight = |lanes: &[EngineGroup<E>]| -> usize {
            lanes.iter().map(|l| l.inflight()).sum()
        };
        let mut rotor = 0usize;
        while !pending.is_empty() || inflight(lanes) > 0 {
            let mut i = 0;
            while i < pending.len() {
                let e = pending[i];
                if let Some(t) = retry_at[e] {
                    if Instant::now() < t {
                        i += 1;
                        continue;
                    }
                    retry_at[e] = None;
                }
                let due = match self.replay {
                    Replay::RealTime => {
                        start.elapsed().as_secs_f64() >= trace[e].arrival_s
                    }
                    Replay::Virtual => inflight(lanes) < window,
                };
                if !due {
                    break;
                }
                let lane = e % n_lanes;
                match lanes[lane].submit(self.request(e as u64, &trace[e]))? {
                    SubmitOutcome::Routed(_) => {
                        streak[e] = 0;
                        pending.remove(i);
                    }
                    SubmitOutcome::Deferred { retry_after_ms } => {
                        if self.exhausted(streak[e]) {
                            completions.push(
                                self.give_up_completion(e, &trace[e], start));
                            pending.remove(i);
                            continue;
                        }
                        retry_at[e] = Some(backoff(retry_after_ms,
                                                   &mut streak[e], &mut rng));
                        i += 1;
                    }
                    SubmitOutcome::Rejected => {
                        if self.exhausted(streak[e]) {
                            completions.push(
                                self.give_up_completion(e, &trace[e], start));
                            pending.remove(i);
                            continue;
                        }
                        retry_at[e] = Some(backoff(2, &mut streak[e],
                                                   &mut rng));
                        break;
                    }
                }
            }
            // One lane gets a bounded wait, the others a non-blocking
            // sweep; the rotor advances every pass so waiting is shared.
            for k in 0..n_lanes {
                let lane = (rotor + k) % n_lanes;
                let wait = if k == 0 {
                    Duration::from_millis(1)
                } else {
                    Duration::ZERO
                };
                if let Some(c) = lanes[lane].poll(wait)? {
                    completions.push(c);
                }
            }
            rotor = (rotor + 1) % n_lanes;
        }
        Ok(completions)
    }
}
