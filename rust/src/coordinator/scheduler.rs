//! Trace-driven scheduling: replays a timed arrival trace against a
//! decode engine or a sharded [`EngineGroup`] (continuous batching
//! happens inside the engines), used by the serving benchmark and the
//! end-to-end serving tests. Arrivals can be replayed in real time or in
//! virtual time (as fast as the fleet can go, arrival order preserved).
//!
//! Requests are numbered `0..n` in arrival order in both modes, so runs
//! over the same trace are comparable per-request across replay modes,
//! shard counts, and engine implementations.

use std::time::{Duration, Instant};

use anyhow::Result;

use super::request::{Completion, Request};
use super::shard::{EngineGroup, SubmitOutcome};
use super::DecodeEngine;
use crate::workload::trace::TracedRequest;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Replay {
    /// Honour wall-clock arrival times (sleeps while idle).
    RealTime,
    /// Submit each request as soon as the fleet has admission headroom
    /// (throughput-oriented; arrival order preserved).
    Virtual,
}

pub struct TraceRunner {
    pub replay: Replay,
    /// Per-request deadline applied at submission (`None` = unbounded):
    /// each request must finish within this much time of entering the
    /// fleet or it is stopped with `StopReason::DeadlineExceeded` and
    /// returns its partial generation. Lets overload replays bound
    /// tail latency the way a deadline-aware client would.
    pub deadline: Option<Duration>,
}

impl Default for TraceRunner {
    fn default() -> Self {
        TraceRunner { replay: Replay::Virtual, deadline: None }
    }
}

impl TraceRunner {
    fn request(&self, id: u64, t: &TracedRequest) -> Request {
        let mut req = Request::new(id, t.episode.prompt.clone(), t.max_new);
        if let Some(d) = self.deadline {
            req.deadline = Some(Instant::now() + d);
        }
        req
    }

    /// Replay against a single engine on the caller's thread (the
    /// pre-sharding behaviour; equivalent to a 1-shard group).
    pub fn run<E: DecodeEngine>(&self, engine: &mut E, trace: &[TracedRequest])
                                -> Result<Vec<Completion>> {
        let mut completions = Vec::new();
        let start = Instant::now();
        let mut next = 0usize;
        let mut id = 0u64;
        // Same up-front guard as run_group: a clean error beats the
        // engine's submit assert.
        let max_prompt = engine.max_prompt_len();
        if let Some(t) = trace.iter().find(|t| t.episode.prompt.len() > max_prompt)
        {
            anyhow::bail!("trace prompt of {} tokens exceeds the engine's \
                           max prompt length {max_prompt}",
                          t.episode.prompt.len());
        }
        while next < trace.len() || !engine.idle() {
            // Admit everything whose arrival time has passed.
            while next < trace.len() {
                let due = match self.replay {
                    Replay::RealTime => {
                        start.elapsed().as_secs_f64() >= trace[next].arrival_s
                    }
                    Replay::Virtual => true,
                };
                if !due {
                    break;
                }
                engine.submit(self.request(id, &trace[next]));
                id += 1;
                next += 1;
                // In virtual mode admit at most one burst per step so the
                // queue still exercises batching decisions.
                if self.replay == Replay::Virtual
                    && engine.pending() >= engine.batch_size()
                {
                    break;
                }
            }
            if engine.idle() {
                // Real-time replay with nothing due yet: wait briefly.
                std::thread::sleep(Duration::from_millis(1));
                continue;
            }
            completions.extend(engine.step()?);
        }
        Ok(completions)
    }

    /// Replay against a sharded [`EngineGroup`]: the router dispatches
    /// admitted requests, shards decode concurrently, and completions
    /// fan back in. A 1-shard group reproduces `run`'s per-request
    /// output exactly (content-deterministic engines), which the serving
    /// tests assert. Admission backpressure ([`SubmitOutcome::Rejected`])
    /// is handled as a well-behaved client would: hold the request and
    /// retry once completions free capacity, so no trace entry is lost.
    pub fn run_group<E: DecodeEngine>(&self, group: &mut EngineGroup<E>,
                                      trace: &[TracedRequest])
                                      -> Result<Vec<Completion>> {
        let mut completions = Vec::with_capacity(trace.len());
        let start = Instant::now();
        let mut next = 0usize;
        let mut id = 0u64;
        let window = group.admission_window();
        // Fail on the caller's thread with a clear message instead of
        // assert-panicking inside a shard (which would only surface as
        // "shard exited with requests in flight").
        let max_prompt = group.max_prompt_len();
        if let Some(t) = trace.iter().find(|t| t.episode.prompt.len() > max_prompt)
        {
            anyhow::bail!("trace prompt of {} tokens exceeds the engines' \
                           max prompt length {max_prompt}",
                          t.episode.prompt.len());
        }
        while next < trace.len() || group.inflight() > 0 {
            while next < trace.len() {
                let due = match self.replay {
                    Replay::RealTime => {
                        start.elapsed().as_secs_f64() >= trace[next].arrival_s
                    }
                    // Keep a bounded backlog so shard queues stay warm
                    // without submitting the whole trace up front.
                    Replay::Virtual => group.inflight() < window,
                };
                if !due {
                    break;
                }
                match group.submit(self.request(id, &trace[next]))? {
                    SubmitOutcome::Routed(_) => {
                        id += 1;
                        next += 1;
                    }
                    // Every shard is at capacity: poll below, retry this
                    // entry on the next pass (capacity frees as
                    // completions land, so this cannot livelock).
                    SubmitOutcome::Rejected => break,
                }
            }
            if let Some(c) = group.poll(Duration::from_millis(1))? {
                completions.push(c);
            }
        }
        Ok(completions)
    }
}
