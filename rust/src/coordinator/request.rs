//! Request / completion types flowing through the coordinator.

use std::time::Duration;

/// A generation request (token-level; the workload layer produces the
//  prompts).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// Why a generation stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    Eos,
    MaxNewTokens,
    ContextFull,
}

impl StopReason {
    /// The one stop decision every decode engine applies after emitting
    /// `tok` (the PJRT engine and `SimEngine` both call this, so their
    /// stop semantics cannot diverge — the N-shard parity tests rely on
    /// that). `cached_len` counts tokens whose KV is in cache: the
    /// just-emitted token is not yet cached.
    pub fn decide(tok: i32, eos: i32, n_generated: usize, max_new: usize,
                  cached_len: usize, max_seq: usize) -> Option<StopReason> {
        if tok == eos {
            Some(StopReason::Eos)
        } else if n_generated >= max_new {
            Some(StopReason::MaxNewTokens)
        } else if cached_len + 2 >= max_seq {
            Some(StopReason::ContextFull)
        } else {
            None
        }
    }
}

/// Per-request sparsity / accuracy diagnostics collected by the engine.
#[derive(Debug, Clone, Default)]
pub struct SeqStats {
    /// (context length, activated tokens per KV head) at each decode step
    /// of layer 0 — the Fig 9a distribution.
    pub activated: Vec<(usize, f64)>,
    /// Sum / count of gate-vs-oracle block recall (when tracking enabled).
    pub recall_sum: f64,
    pub recall_n: u64,
    /// KV bytes gathered for attention across the generation (I/O proxy).
    pub kv_bytes_touched: u64,
}

impl SeqStats {
    pub fn mean_recall(&self) -> Option<f64> {
        if self.recall_n == 0 {
            None
        } else {
            Some(self.recall_sum / self.recall_n as f64)
        }
    }

    pub fn mean_activated(&self) -> Option<f64> {
        if self.activated.is_empty() {
            None
        } else {
            Some(self.activated.iter().map(|(_, a)| a).sum::<f64>()
                / self.activated.len() as f64)
        }
    }
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub generated: Vec<i32>,
    pub stop: StopReason,
    /// Queue admission -> first generated token.
    pub ttft: Duration,
    /// Queue admission -> completion.
    pub e2e: Duration,
    pub stats: SeqStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_means() {
        let mut s = SeqStats::default();
        assert!(s.mean_recall().is_none());
        assert!(s.mean_activated().is_none());
        s.activated.push((10, 4.0));
        s.activated.push((20, 6.0));
        s.recall_sum = 1.5;
        s.recall_n = 2;
        assert_eq!(s.mean_activated(), Some(5.0));
        assert_eq!(s.mean_recall(), Some(0.75));
    }
}
