//! Request / completion / event types flowing through the coordinator.
//!
//! A request's lifecycle is an **event stream**, not a single terminal
//! completion: engines emit [`EngineEvent::Started`] at admission, one
//! [`EngineEvent::Token`] per generated token, and a final
//! [`EngineEvent::Finished`] carrying the [`Completion`]. The legacy
//! `step() -> Vec<Completion>` view is derived from the stream (see
//! [`DecodeEngine::step_events`]), so non-streaming callers are
//! unaffected while the serving layer can stream deltas and act on a
//! request *mid-decode* (cancellation, deadlines).
//!
//! [`DecodeEngine::step_events`]: super::DecodeEngine::step_events

use std::collections::{HashSet, VecDeque};
use std::time::{Duration, Instant};

/// Scheduling class for a request. Ordering is by urgency: `Batch` sorts
/// below `Interactive`, so "lowest priority" (`min`) picks the batch
/// traffic first when a preemption victim must be chosen.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Throughput traffic: first to be preempted under memory pressure.
    Batch,
    /// Latency-sensitive traffic (the default, matching pre-priority
    /// behaviour where every request was implicitly interactive).
    Interactive,
}

impl Default for Priority {
    fn default() -> Priority {
        Priority::Interactive
    }
}

impl Priority {
    /// Wire name used by the JSON-lines protocol and reports.
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Batch => "batch",
            Priority::Interactive => "interactive",
        }
    }

    /// Parse a wire name (`"batch"` / `"interactive"`).
    pub fn from_wire(s: &str) -> Option<Priority> {
        match s {
            "batch" => Some(Priority::Batch),
            "interactive" => Some(Priority::Interactive),
            _ => None,
        }
    }
}

/// A generation request (token-level; the workload layer produces the
//  prompts).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Absolute per-request deadline. A request still decoding (or still
    /// queued) past this instant is stopped at the next engine step
    /// boundary with [`StopReason::DeadlineExceeded`], returning whatever
    /// it generated so far. `None` = no deadline.
    pub deadline: Option<Instant>,
    /// The caller wants per-token [`EngineEvent::Token`] deltas. Engines
    /// emit token events natively either way; this flag gates whether
    /// the shard layer forwards them across the completion channel, so
    /// non-streaming traffic pays no per-token cross-thread cost.
    pub stream: bool,
    /// Scheduling class: under KV memory pressure, lower-priority
    /// requests are preempted (pages dropped, requeued for re-prefill)
    /// before higher-priority ones are ever touched.
    pub priority: Priority,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
        Request {
            id,
            prompt,
            max_new,
            deadline: None,
            stream: false,
            priority: Priority::default(),
        }
    }

    pub fn with_deadline(mut self, deadline: Instant) -> Request {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_stream(mut self) -> Request {
        self.stream = true;
        self
    }

    pub fn with_priority(mut self, priority: Priority) -> Request {
        self.priority = priority;
        self
    }
}

/// A request waiting in a queue (shard overflow queue or an engine's
/// internal queue), together with the state needed to resume it if it
/// was preempted mid-decode. Fresh requests carry an empty `resume`;
/// preempted ones carry everything generated so far so a re-prefill
/// reproduces the exact token stream (and so a cancel/deadline that
/// lands while requeued still returns the partial generation).
#[derive(Debug, Clone)]
pub struct QueuedReq {
    pub req: Request,
    /// Original arrival instant (preserved across preemptions so e2e
    /// latency and deadline checks measure from first submission).
    pub arrived: Instant,
    /// Tokens already generated (and already streamed) before a
    /// preemption; empty for fresh requests.
    pub resume: Vec<i32>,
    /// When the first token was produced, if any (preserved across
    /// preemptions so TTFT is measured once).
    pub first_token_at: Option<Instant>,
    /// How many times this request has been preempted-and-requeued.
    pub retries: u32,
    /// Pinned to the shard queue it sits in: work stealing skips it.
    /// Set by the router under prefix routing for requests placed on
    /// their prefix-affinity shard — stealing one would move it away
    /// from the cached (or about-to-be-cached) KV blocks it shares.
    pub sticky: bool,
}

impl QueuedReq {
    pub fn fresh(req: Request, arrived: Instant) -> QueuedReq {
        QueuedReq { req, arrived, resume: Vec::new(), first_token_at: None,
                    retries: 0, sticky: false }
    }

    /// Rebuild a queue record for a request rescued off a dead shard:
    /// same shape as a preemption requeue — `resume` carries the tokens
    /// the router has already observed (and already streamed), so
    /// re-admission replays them without re-emitting and the client
    /// stream continues bit-identically at the next index. Never sticky:
    /// the rescuing shard is by definition not the affinity placement.
    pub fn resumed(req: Request, arrived: Instant, resume: Vec<i32>,
                   first_token_at: Option<Instant>, retries: u32) -> QueuedReq {
        QueuedReq { req, arrived, resume, first_token_at, retries,
                    sticky: false }
    }
}

/// Why a generation stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    Eos,
    MaxNewTokens,
    ContextFull,
    /// Cancelled in flight (client disconnect, eviction, or an explicit
    /// cancel); the completion carries the tokens generated so far.
    Cancelled,
    /// The request's deadline passed before it finished; the completion
    /// carries the tokens generated so far (possibly none, if it expired
    /// while still queued).
    DeadlineExceeded,
    /// The request was preempted under KV memory pressure more times
    /// than its retry budget allows, or could never fit the shard's page
    /// pool at all; the completion carries the tokens generated so far.
    ResourceExhausted,
}

impl StopReason {
    /// The one stop decision every decode engine applies after emitting
    /// `tok` (the PJRT engine and `SimEngine` both call this, so their
    /// stop semantics cannot diverge — the N-shard parity tests rely on
    /// that). `cached_len` counts tokens whose KV is in cache: the
    /// just-emitted token is not yet cached.
    pub fn decide(tok: i32, eos: i32, n_generated: usize, max_new: usize,
                  cached_len: usize, max_seq: usize) -> Option<StopReason> {
        if tok == eos {
            Some(StopReason::Eos)
        } else if n_generated >= max_new {
            Some(StopReason::MaxNewTokens)
        } else if cached_len + 2 >= max_seq {
            Some(StopReason::ContextFull)
        } else {
            None
        }
    }

    /// The one *control* stop decision, applied at every engine step
    /// boundary before any decode work (again shared by the PJRT engine
    /// and `SimEngine` so the two cannot diverge): an explicit cancel
    /// wins over a deadline, and both free the request's slot and KV
    /// pages in the reap that immediately follows.
    pub fn control(cancelled: bool, deadline: Option<Instant>,
                   now: Instant) -> Option<StopReason> {
        if cancelled {
            Some(StopReason::Cancelled)
        } else if deadline.map(|d| now >= d).unwrap_or(false) {
            Some(StopReason::DeadlineExceeded)
        } else {
            None
        }
    }

    /// Wire name used by the JSON-lines protocol and reports.
    pub fn as_str(self) -> &'static str {
        match self {
            StopReason::Eos => "eos",
            StopReason::MaxNewTokens => "max_new",
            StopReason::ContextFull => "context_full",
            StopReason::Cancelled => "cancelled",
            StopReason::DeadlineExceeded => "deadline",
            StopReason::ResourceExhausted => "resource_exhausted",
        }
    }
}

/// Queue-side control scan, shared **verbatim** by the PJRT engine and
/// `SimEngine` so their queued-request cancel/deadline semantics cannot
/// diverge (the slot-side scan differs only in slot types and stays
/// per-engine): remove cancelled or deadline-expired requests still
/// waiting in the engine's internal queue and append their completions
/// to `done_early` for the next reap — they never occupy a slot. E2e is
/// measured from the original arrival. Fresh requests report zero TTFT
/// and an empty generation; a preempted-then-requeued request returns
/// its partial generation and the TTFT it already achieved.
pub(crate) fn expire_queued(queue: &mut VecDeque<QueuedReq>,
                            cancels: &mut HashSet<u64>,
                            done_early: &mut Vec<Completion>,
                            now: Instant) {
    let mut i = 0;
    while i < queue.len() {
        let q = &queue[i];
        let cancelled = cancels.contains(&q.req.id);
        match StopReason::control(cancelled, q.req.deadline, now) {
            Some(stop) => {
                let q = queue.remove(i).unwrap();
                cancels.remove(&q.req.id);
                done_early.push(Completion {
                    id: q.req.id,
                    prompt_len: q.req.prompt.len(),
                    generated: q.resume,
                    stop,
                    ttft: q.first_token_at
                        .map(|t| t.saturating_duration_since(q.arrived))
                        .unwrap_or(Duration::ZERO),
                    e2e: now.saturating_duration_since(q.arrived),
                    stats: SeqStats::default(),
                });
            }
            None => i += 1,
        }
    }
}

/// One step of a request's lifecycle, emitted by
/// [`DecodeEngine::step_events`]. Events for a given request id always
/// arrive in order: `Started`, then `Token` with consecutive `index`es
/// starting at 0, then exactly one `Finished` (whose completion's
/// `generated` is the concatenation of the tokens — the streaming-parity
/// tests pin that).
///
/// [`DecodeEngine::step_events`]: super::DecodeEngine::step_events
#[derive(Debug, Clone)]
pub enum EngineEvent {
    /// The request was admitted into a batch slot (prefill begins).
    Started { id: u64 },
    /// One generated token; `index` is its position in the generation.
    Token { id: u64, tok: i32, index: usize },
    /// The request was preempted mid-decode (pages dropped, requeued for
    /// re-prefill). Not terminal: tokens already streamed stay valid and
    /// the stream resumes at the next `index` after re-admission, so a
    /// request may see several `Preempted` events but never a gap or a
    /// repeat in its token indices.
    Preempted { id: u64 },
    /// Terminal: the request finished, was cancelled, or expired.
    Finished(Completion),
}

/// Per-request sparsity / accuracy diagnostics collected by the engine.
#[derive(Debug, Clone, Default)]
pub struct SeqStats {
    /// (context length, activated tokens per KV head) at each decode step
    /// of layer 0 — the Fig 9a distribution.
    pub activated: Vec<(usize, f64)>,
    /// Sum / count of gate-vs-oracle block recall (when tracking enabled).
    pub recall_sum: f64,
    pub recall_n: u64,
    /// KV bytes gathered for attention across the generation (I/O proxy).
    pub kv_bytes_touched: u64,
}

impl SeqStats {
    pub fn mean_recall(&self) -> Option<f64> {
        if self.recall_n == 0 {
            None
        } else {
            Some(self.recall_sum / self.recall_n as f64)
        }
    }

    pub fn mean_activated(&self) -> Option<f64> {
        if self.activated.is_empty() {
            None
        } else {
            Some(self.activated.iter().map(|(_, a)| a).sum::<f64>()
                / self.activated.len() as f64)
        }
    }
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub generated: Vec<i32>,
    pub stop: StopReason,
    /// Queue admission -> first generated token.
    pub ttft: Duration,
    /// Queue admission -> completion.
    pub e2e: Duration,
    pub stats: SeqStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_means() {
        let mut s = SeqStats::default();
        assert!(s.mean_recall().is_none());
        assert!(s.mean_activated().is_none());
        s.activated.push((10, 4.0));
        s.activated.push((20, 6.0));
        s.recall_sum = 1.5;
        s.recall_n = 2;
        assert_eq!(s.mean_activated(), Some(5.0));
        assert_eq!(s.mean_recall(), Some(0.75));
    }

    #[test]
    fn control_stop_orders_cancel_over_deadline() {
        let now = Instant::now();
        let past = now - Duration::from_millis(10);
        let future = now + Duration::from_secs(10);
        assert_eq!(StopReason::control(false, None, now), None);
        assert_eq!(StopReason::control(false, Some(future), now), None);
        assert_eq!(StopReason::control(false, Some(past), now),
                   Some(StopReason::DeadlineExceeded));
        assert_eq!(StopReason::control(true, Some(past), now),
                   Some(StopReason::Cancelled), "cancel beats deadline");
        assert_eq!(StopReason::control(true, None, now),
                   Some(StopReason::Cancelled));
        // The deadline boundary itself counts as expired.
        assert_eq!(StopReason::control(false, Some(now), now),
                   Some(StopReason::DeadlineExceeded));
    }

    #[test]
    fn request_builder_sets_deadline_and_stream() {
        let r = Request::new(3, vec![1, 2], 8);
        assert!(r.deadline.is_none());
        assert!(!r.stream);
        let d = Instant::now();
        let r = r.with_deadline(d).with_stream();
        assert_eq!(r.deadline, Some(d));
        assert!(r.stream);
    }

    #[test]
    fn expire_queued_removes_cancelled_and_expired_only() {
        let now = Instant::now();
        let mut queue: VecDeque<QueuedReq> = VecDeque::new();
        queue.push_back(QueuedReq::fresh(Request::new(0, vec![1], 4), now)); // survives
        queue.push_back(QueuedReq::fresh(Request::new(1, vec![2], 4), now)); // cancelled
        queue.push_back(QueuedReq::fresh(
            Request::new(2, vec![3], 4)
                .with_deadline(now - Duration::from_millis(1)),
            now,
        )); // expired
        let mut cancels: HashSet<u64> = [1].into_iter().collect();
        let mut done = Vec::new();
        expire_queued(&mut queue, &mut cancels, &mut done,
                      now + Duration::from_millis(1));
        assert_eq!(queue.len(), 1);
        assert_eq!(queue[0].req.id, 0);
        assert!(cancels.is_empty(), "handled cancel marks are consumed");
        assert_eq!(done.len(), 2);
        let stop_of = |id: u64| done.iter().find(|c| c.id == id).unwrap().stop;
        assert_eq!(stop_of(1), StopReason::Cancelled);
        assert_eq!(stop_of(2), StopReason::DeadlineExceeded);
        assert!(done.iter().all(|c| c.generated.is_empty()));
        assert!(done.iter().all(|c| c.ttft == Duration::ZERO));
    }

    #[test]
    fn expire_queued_returns_partial_generation_for_preempted_requests() {
        let start = Instant::now();
        let first_tok = start + Duration::from_millis(5);
        let now = start + Duration::from_millis(20);
        let mut queue: VecDeque<QueuedReq> = VecDeque::new();
        queue.push_back(QueuedReq {
            req: Request::new(7, vec![1, 2, 3], 16),
            arrived: start,
            resume: vec![10, 11, 12],
            first_token_at: Some(first_tok),
            retries: 1,
            sticky: false,
        });
        let mut cancels: HashSet<u64> = [7].into_iter().collect();
        let mut done = Vec::new();
        expire_queued(&mut queue, &mut cancels, &mut done, now);
        assert_eq!(done.len(), 1);
        let c = &done[0];
        assert_eq!(c.stop, StopReason::Cancelled);
        assert_eq!(c.generated, vec![10, 11, 12],
                   "a preempted request returns its partial generation");
        assert_eq!(c.ttft, Duration::from_millis(5));
        assert_eq!(c.e2e, Duration::from_millis(20));
    }

    #[test]
    fn stop_reason_wire_names() {
        for (s, name) in [
            (StopReason::Eos, "eos"),
            (StopReason::MaxNewTokens, "max_new"),
            (StopReason::ContextFull, "context_full"),
            (StopReason::Cancelled, "cancelled"),
            (StopReason::DeadlineExceeded, "deadline"),
            (StopReason::ResourceExhausted, "resource_exhausted"),
        ] {
            assert_eq!(s.as_str(), name);
        }
    }

    #[test]
    fn priority_orders_batch_below_interactive() {
        assert!(Priority::Batch < Priority::Interactive);
        assert_eq!(Priority::default(), Priority::Interactive);
        assert_eq!(Priority::from_wire("batch"), Some(Priority::Batch));
        assert_eq!(Priority::from_wire("interactive"),
                   Some(Priority::Interactive));
        assert_eq!(Priority::from_wire("urgent"), None);
        assert_eq!(Priority::Batch.as_str(), "batch");
        assert_eq!(Priority::Interactive.as_str(), "interactive");
        let r = Request::new(1, vec![1], 4);
        assert_eq!(r.priority, Priority::Interactive);
        assert_eq!(r.with_priority(Priority::Batch).priority, Priority::Batch);
    }
}
