//! The decode engine: continuous batching over the AOT executables.
//!
//! Per decode token and layer, the engine performs the paper's inference
//! loop (Fig 3):
//!   1. `layer_pre` (device): QKV projections + RoPE + the AttnGate query.
//!   2. host: append K/V to the paged cache, pre-RoPE K to the pending
//!      K-compression block (flushing a new compressed entry every
//!      `block_size` tokens, §3.2), RoPE'd K to the Quest min/max
//!      metadata.
//!   3. host: block selection under the configured policy (§3.1) — gate
//!      top-k / threshold, oracle, Quest, or dense — with the partial
//!      last block always force-activated.
//!   4. host: gather the selected pages into the staging buffer (this is
//!      the I/O the paper saves: bytes moved scale with the budget).
//!   5. `layer_post_sel_t{T}` / `layer_post_selh_t{T}` / dense (device):
//!      block-sparse attention + the rest of the layer.
//! Then `lm_head` + sampling, once per token for the whole batch.

use std::collections::{HashMap, VecDeque};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::metrics::Metrics;
use super::request::{Completion, Request, SeqStats, StopReason};
use super::sampling;
use crate::gate;
use crate::kvcache::offload::{OffloadConfig, TieredKv};
use crate::kvcache::{KcompCache, PagedKvPool, SeqKv};
use crate::model::{ModelConfig, ParamStore};
use crate::runtime::{Arg, DeviceTensor, HostTensor, Runtime};
use crate::sparse::policy::{select_budget, select_threshold, select_top_p, Policy,
                            Selection};
use crate::sparse::quest::QuestMeta;
use crate::sparse::topk::{merge_mandatory, topk_indices};
use crate::util::rng::Rng;
use crate::workload::Vocab;

#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    pub policy: Policy,
    /// Hybrid ablation (§5.2): this many leading layers run dense.
    pub dense_first_layers: usize,
    /// Sparse attention block size (tokens); also the KV page size.
    pub block_size: usize,
    pub max_new: usize,
    /// 0.0 = greedy.
    pub temperature: f32,
    pub seed: u64,
    /// Record gate-vs-oracle recall at every step (slow; diagnostics).
    pub track_recall: bool,
    /// KV offload simulation (§3.2): fast-tier capacity in pages
    /// (0 = disabled). Pages touched by attention gathers go through an
    /// LRU fast tier; misses are charged as slow-tier fetches.
    pub offload_fast_pages: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: Policy::Dense,
            dense_first_layers: 0,
            block_size: 16,
            max_new: 32,
            temperature: 0.0,
            seed: 0,
            track_recall: false,
            offload_fast_pages: 0,
        }
    }
}

/// Per-slot sequence state.
struct Slot {
    req: Request,
    admitted: Instant,
    first_token: Option<Instant>,
    /// All tokens: prompt + generated (last one not yet in KV cache).
    tokens: Vec<i32>,
    /// Tokens whose KV is cached.
    len: usize,
    kv: Vec<SeqKv>,          // per layer
    kcomp: Vec<KcompCache>,  // per layer
    quest: Vec<QuestMeta>,   // per layer
    generated: Vec<i32>,
    stats: SeqStats,
    stop: Option<StopReason>,
}

pub struct Engine {
    pub rt: Rc<Runtime>,
    pub cfg: ModelConfig,
    pub ecfg: EngineConfig,
    params: ParamStore,
    pool: PagedKvPool,
    slots: Vec<Option<Slot>>,
    queue: VecDeque<(Request, Instant)>,
    rng: Rng,
    pub metrics: Metrics,
    pub vocab: Vocab,
    batch: usize,
    max_seq: usize,
    /// Resident device copies of every weight tensor (uploaded once).
    dev: HashMap<String, DeviceTensor>,
    /// Per-layer wk_gate host copies (hot in the kcomp update).
    wk_gates: Vec<Vec<f32>>,
    /// Current decode step's q_rope (for the oracle / recall paths).
    current_q: Vec<f32>,
    /// Optional tiered-KV offload accounting (§3.2).
    pub offload: Option<TieredKv>,
}

impl Engine {
    pub fn new(rt: Rc<Runtime>, params: ParamStore, gates: ParamStore,
               ecfg: EngineConfig) -> Result<Engine> {
        let cfg = ModelConfig::from_json(&rt.manifest.model)?;
        let batch = rt.manifest.aot.get("decode_batch")?.as_usize()?;
        let max_seq = rt.manifest.aot.get("prefill_len")?.as_usize()?;
        if max_seq % ecfg.block_size != 0 {
            bail!("block size {} must divide max_seq {max_seq}", ecfg.block_size);
        }
        let pages_per_seq = max_seq / ecfg.block_size + 1;
        let capacity = batch * cfg.n_layers * pages_per_seq;
        let pool = PagedKvPool::new(capacity, cfg.n_kv_heads, cfg.head_dim,
                                    ecfg.block_size);
        let slots = (0..batch).map(|_| None).collect();
        let wk_gates = (0..cfg.n_layers)
            .map(|l| Ok(gates.get(&format!("l{l}.wk_gate"))?.as_f32()?.to_vec()))
            .collect::<Result<Vec<_>>>()?;
        let offload = if ecfg.offload_fast_pages > 0 {
            Some(TieredKv::new(OffloadConfig {
                fast_capacity: ecfg.offload_fast_pages,
                fetch_s_per_byte: 1e-10, // ~10 GB/s host link analog
                page_bytes: 2 * cfg.n_kv_heads * ecfg.block_size * cfg.head_dim * 4,
            }))
        } else {
            None
        };
        // Upload all weights once; the decode hot path only ships
        // activations and gathered KV.
        let mut dev = HashMap::new();
        for (spec, t) in params.specs.iter().zip(&params.tensors) {
            dev.insert(spec.name.clone(), rt.upload(t)?);
        }
        for (spec, t) in gates.specs.iter().zip(&gates.tensors) {
            dev.insert(spec.name.clone(), rt.upload(t)?);
        }
        Ok(Engine {
            rng: Rng::new(ecfg.seed),
            rt,
            cfg,
            ecfg,
            params,
            pool,
            slots,
            queue: VecDeque::new(),
            dev,
            metrics: Metrics::new(),
            vocab: Vocab::default(),
            batch,
            max_seq,
            wk_gates,
            current_q: Vec::new(),
            offload,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Free pages in the KV pool (leak detection in tests).
    pub fn pool_free(&self) -> usize {
        self.pool.free_pages()
    }

    pub fn pool_capacity(&self) -> usize {
        self.pool.capacity()
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn submit(&mut self, req: Request) {
        assert!(req.prompt.len() + 2 < self.max_seq,
                "prompt {} too long for context {}", req.prompt.len(), self.max_seq);
        self.metrics.start_clock();
        self.queue.push_back((req, Instant::now()));
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active() == 0
    }

    /// Run everything currently queued to completion.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.idle() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    /// One engine iteration: admit+prefill if there are waiting requests
    /// and free slots, otherwise decode one token for the running batch.
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        if !self.queue.is_empty() && self.slots.iter().any(|s| s.is_none()) {
            self.admit_and_prefill()?;
        } else if self.active() > 0 {
            self.decode_step()?;
        }
        Ok(self.reap())
    }

    // ------------------------------------------------------------------
    // Prefill
    // ------------------------------------------------------------------

    fn admit_and_prefill(&mut self) -> Result<()> {
        let t0 = Instant::now();
        let mut new_slots: Vec<usize> = Vec::new();
        for i in 0..self.batch {
            if self.slots[i].is_none() {
                if let Some((req, admitted)) = self.queue.pop_front() {
                    self.slots[i] = Some(Slot {
                        tokens: req.prompt.clone(),
                        len: 0,
                        kv: (0..self.cfg.n_layers).map(|_| SeqKv::new()).collect(),
                        kcomp: (0..self.cfg.n_layers)
                            .map(|_| KcompCache::new(&self.cfg, self.ecfg.block_size))
                            .collect(),
                        quest: (0..self.cfg.n_layers)
                            .map(|_| QuestMeta::new(&self.cfg, self.ecfg.block_size,
                                                    self.max_seq))
                            .collect(),
                        generated: Vec::new(),
                        stats: SeqStats::default(),
                        stop: None,
                        req,
                        admitted,
                        first_token: None,
                    });
                    new_slots.push(i);
                }
            }
        }
        if new_slots.is_empty() {
            return Ok(());
        }
        // Padded prefill batch: only new slots get nonzero len.
        let (b, s) = (self.batch, self.max_seq);
        let mut ids = vec![0i32; b * s];
        let mut seq_len = vec![0i32; b];
        for &i in &new_slots {
            let p = &self.slots[i].as_ref().unwrap().req.prompt;
            ids[i * s..i * s + p.len()].copy_from_slice(p);
            seq_len[i] = p.len() as i32;
        }
        let ids_t = HostTensor::i32(vec![b, s], ids);
        let len_t = HostTensor::i32(vec![b], seq_len);
        let names: Vec<String> =
            self.params.specs.iter().map(|sp| sp.name.clone()).collect();
        let outs = {
            let mut args: Vec<Arg> = Vec::with_capacity(names.len() + 2);
            for n in &names {
                args.push(Arg::Dev(&self.dev[n.as_str()]));
            }
            args.push(Arg::Host(&ids_t));
            args.push(Arg::Host(&len_t));
            self.rt.call("prefill", &args)?
        };
        let lg = outs[0].as_f32()?;
        let kr = outs[1].as_f32()?;
        let vv = outs[2].as_f32()?;
        let kp = outs[3].as_f32()?;
        let (hkv, dh, l_n) = (self.cfg.n_kv_heads, self.cfg.head_dim, self.cfg.n_layers);
        let vocab = self.cfg.vocab;
        // cache layout [L, B, Hkv, S, dh]
        let idx = |l: usize, bi: usize, h: usize, t: usize| {
            (((l * b + bi) * hkv + h) * s + t) * dh
        };
        let mut krow = vec![0f32; hkv * dh];
        let mut vrow = vec![0f32; hkv * dh];
        let mut prow = vec![0f32; hkv * dh];
        for &i in &new_slots {
            let plen = self.slots[i].as_ref().unwrap().req.prompt.len();
            for t in 0..plen {
                for l in 0..l_n {
                    for h in 0..hkv {
                        let o = idx(l, i, h, t);
                        krow[h * dh..(h + 1) * dh].copy_from_slice(&kr[o..o + dh]);
                        vrow[h * dh..(h + 1) * dh].copy_from_slice(&vv[o..o + dh]);
                        prow[h * dh..(h + 1) * dh].copy_from_slice(&kp[o..o + dh]);
                    }
                    let slot = self.slots[i].as_mut().unwrap();
                    slot.kv[l].append(&mut self.pool, &krow, &vrow)?;
                    slot.quest[l].append(&krow);
                    slot.kcomp[l].append(&self.cfg, &self.wk_gates[l], &prow);
                }
            }
            // First generated token from logits[i, plen-1].
            let row = &lg[(i * s + plen - 1) * vocab..(i * s + plen) * vocab];
            let tok = sampling::sample(row, self.ecfg.temperature, &mut self.rng);
            let slot = self.slots[i].as_mut().unwrap();
            slot.len = plen;
            slot.tokens.push(tok);
            slot.generated.push(tok);
            slot.first_token = Some(Instant::now());
            self.check_stop(i, tok);
        }
        self.metrics.prefill_s.push(t0.elapsed().as_secs_f64());
        Ok(())
    }

    // ------------------------------------------------------------------
    // Decode
    // ------------------------------------------------------------------

    fn decode_step(&mut self) -> Result<()> {
        let t0 = Instant::now();
        let (b, d) = (self.batch, self.cfg.d_model);
        let (hkv, _h_all, dh, dg) = (self.cfg.n_kv_heads, self.cfg.n_heads,
                                    self.cfg.head_dim, self.cfg.d_gate);
        let active: Vec<usize> = (0..b).filter(|&i| self.slots[i].is_some()).collect();
        // Embed current tokens (host: one row copy per sequence).
        let mut x = vec![0f32; b * d];
        let mut pos = vec![0i32; b];
        {
            let emb = self.params.get("emb")?.as_f32()?;
            for &i in &active {
                let slot = self.slots[i].as_ref().unwrap();
                let tok = *slot.tokens.last().unwrap() as usize;
                x[i * d..(i + 1) * d].copy_from_slice(&emb[tok * d..(tok + 1) * d]);
                pos[i] = slot.len as i32;
            }
        }
        let mut x_t = HostTensor::f32(vec![b, d], x);
        let pos_t = HostTensor::i32(vec![b], pos);

        for l in 0..self.cfg.n_layers {
            // 1. layer_pre
            let outs = {
                let args = [
                    Arg::Host(&x_t),
                    Arg::Host(&pos_t),
                    Arg::Dev(&self.dev[&format!("l{l}.wq")]),
                    Arg::Dev(&self.dev[&format!("l{l}.wk")]),
                    Arg::Dev(&self.dev[&format!("l{l}.wv")]),
                    Arg::Dev(&self.dev[&format!("l{l}.ln1")]),
                    Arg::Dev(&self.dev[&format!("l{l}.wq_gate")]),
                ];
                self.rt.call("layer_pre", &args)?
            };
            let k_rope = outs[1].as_f32()?;
            let v_new = outs[2].as_f32()?;
            let k_pre = outs[3].as_f32()?;
            let q_gate_all = outs[4].as_f32()?.to_vec();
            self.current_q = outs[0].as_f32()?.to_vec();

            // 2. cache updates
            for &i in &active {
                let krow = &k_rope[i * hkv * dh..(i + 1) * hkv * dh];
                let vrow = &v_new[i * hkv * dh..(i + 1) * hkv * dh];
                let prow = &k_pre[i * hkv * dh..(i + 1) * hkv * dh];
                let slot = self.slots[i].as_mut().unwrap();
                slot.kv[l].append(&mut self.pool, krow, vrow)?;
                slot.quest[l].append(krow);
                slot.kcomp[l].append(&self.cfg, &self.wk_gates[l], prow);
            }

            // 3. selection
            let effective = if l < self.ecfg.dense_first_layers {
                Policy::Dense
            } else {
                self.ecfg.policy
            };
            let mut selections: Vec<Option<Selection>> = vec![None; b];
            for &i in &active {
                let qg = q_gate_all[i * hkv * dg..(i + 1) * hkv * dg].to_vec();
                let sel = self.select(i, l, effective, &qg)?;
                if l == 0 {
                    self.record_activation(i, l, &sel);
                }
                selections[i] = Some(sel);
            }

            // 4+5. gather + attention
            x_t = self.run_attention(l, &outs[0], &x_t, &active, &selections)?;
        }

        // lm_head + sampling
        let logits = {
            let args = [
                Arg::Host(&x_t),
                Arg::Dev(&self.dev["ln_f"]),
                Arg::Dev(&self.dev["head"]),
            ];
            self.rt.call("lm_head", &args)?
        };
        let lg = logits[0].as_f32()?;
        let vocab = self.cfg.vocab;
        for &i in &active {
            let row = &lg[i * vocab..(i + 1) * vocab];
            let tok = sampling::sample(row, self.ecfg.temperature, &mut self.rng);
            let slot = self.slots[i].as_mut().unwrap();
            slot.len += 1;
            slot.tokens.push(tok);
            slot.generated.push(tok);
            self.check_stop(i, tok);
        }
        self.metrics.decode_step_s.push(t0.elapsed().as_secs_f64());
        Ok(())
    }

    /// Fig 9a accounting: activated tokens per head at layer 0.
    fn record_activation(&mut self, i: usize, l: usize, sel: &Selection) {
        let bs = self.ecfg.block_size;
        let slot = self.slots[i].as_ref().unwrap();
        let ctx = slot.kv[l].len;
        let act = match sel {
            Selection::Dense => ctx as f64,
            Selection::Shared(v) | Selection::PerHead(v) => {
                let per: f64 = v
                    .iter()
                    .map(|row| {
                        row.iter()
                            .map(|&j| slot.kv[l].tokens_in_block(j as usize, bs))
                            .sum::<usize>() as f64
                    })
                    .sum();
                per / v.len().max(1) as f64
            }
        };
        let slot = self.slots[i].as_mut().unwrap();
        slot.stats.activated.push((ctx, act));
    }

    /// Block selection for one slot at one layer (step 3).
    fn select(&mut self, i: usize, l: usize, policy: Policy,
              q_gate: &[f32]) -> Result<Selection> {
        let bs = self.ecfg.block_size;
        let (partial, n_complete) = {
            let kc = &self.slots[i].as_ref().unwrap().kcomp[l];
            (if kc.has_partial() { Some(kc.partial_index()) } else { None },
             kc.n_complete())
        };
        let sel = match policy {
            Policy::Dense => Selection::Dense,
            Policy::GateBudget { budget_tokens } => {
                let kc = &self.slots[i].as_ref().unwrap().kcomp[l];
                let scores = kc.score(&self.cfg, q_gate);
                let k = Policy::block_budget(budget_tokens, bs);
                Selection::Shared(select_budget(&scores, k, partial))
            }
            Policy::GateThreshold { threshold } => {
                let kc = &self.slots[i].as_ref().unwrap().kcomp[l];
                let mut scores = kc.score(&self.cfg, q_gate);
                for row in &mut scores {
                    let n = row.len();
                    if n > 0 {
                        gate::softmax_rows(row, n);
                    }
                }
                Selection::Shared(select_threshold(&scores, threshold, partial))
            }
            Policy::GateTopP { p } => {
                let kc = &self.slots[i].as_ref().unwrap().kcomp[l];
                let mut scores = kc.score(&self.cfg, q_gate);
                for row in &mut scores {
                    let n = row.len();
                    if n > 0 {
                        gate::softmax_rows(row, n);
                    }
                }
                Selection::Shared(select_top_p(&scores, p, partial))
            }
            Policy::Oracle { budget_tokens } => {
                let rows = self.oracle_rows(i, l);
                let k = Policy::block_budget(budget_tokens, bs);
                let mut sel: Vec<Vec<i32>> = Vec::with_capacity(rows.len());
                for row in &rows {
                    let take = if partial.is_some() { k.saturating_sub(1) } else { k };
                    let mut s = topk_indices(&row[..n_complete.min(row.len())], take);
                    if let Some(p) = partial {
                        merge_mandatory(&mut s, p);
                    }
                    sel.push(s);
                }
                Selection::Shared(sel)
            }
            Policy::Quest { budget_tokens } => {
                let k = Policy::block_budget(budget_tokens, bs);
                let g = self.cfg.group_size;
                let dh = self.cfg.head_dim;
                let slot = self.slots[i].as_ref().unwrap();
                let mut sel = Vec::with_capacity(self.cfg.n_heads);
                for qh in 0..self.cfg.n_heads {
                    let kvh = qh / g;
                    let q = &self.current_q[(i * self.cfg.n_heads + qh) * dh..][..dh];
                    let scores = slot.quest[l].scores(kvh, q);
                    let take = if partial.is_some() { k.saturating_sub(1) } else { k };
                    let mut s =
                        topk_indices(&scores[..n_complete.min(scores.len())], take);
                    if let Some(p) = partial {
                        merge_mandatory(&mut s, p);
                    }
                    sel.push(s);
                }
                Selection::PerHead(sel)
            }
        };
        // Recall diagnostics vs the oracle.
        if self.ecfg.track_recall {
            if let Policy::GateBudget { budget_tokens } | Policy::Quest { budget_tokens } =
                policy
            {
                let rows = self.oracle_rows(i, l);
                let k = Policy::block_budget(budget_tokens, bs);
                let orc: Vec<Vec<i32>> = rows
                    .iter()
                    .map(|r| topk_indices(&r[..n_complete.min(r.len())], k))
                    .collect();
                let mut rsum = 0.0;
                let mut rn = 0u64;
                let g = self.cfg.group_size;
                match &sel {
                    Selection::Shared(v) => {
                        for (hh, row) in v.iter().enumerate() {
                            let o = &orc[hh];
                            if !o.is_empty() {
                                let hit = row.iter().filter(|x| o.contains(x)).count();
                                rsum += hit as f64 / o.len() as f64;
                                rn += 1;
                            }
                        }
                    }
                    Selection::PerHead(v) => {
                        for (qh, row) in v.iter().enumerate() {
                            let o = &orc[qh / g];
                            if !o.is_empty() {
                                let hit = row.iter().filter(|x| o.contains(x)).count();
                                rsum += hit as f64 / o.len() as f64;
                                rn += 1;
                            }
                        }
                    }
                    Selection::Dense => {}
                }
                let slot = self.slots[i].as_mut().unwrap();
                slot.stats.recall_sum += rsum;
                slot.stats.recall_n += rn;
            }
        }
        Ok(sel)
    }

    /// Oracle block scores (true attention over the cached keys, §4.2)
    /// for one slot+layer: per-KV-head rows over all blocks (incl.
    /// partial).
    fn oracle_rows(&self, i: usize, l: usize) -> Vec<Vec<f32>> {
        let slot = self.slots[i].as_ref().unwrap();
        let kvl = &slot.kv[l];
        let bs = self.ecfg.block_size;
        let len = kvl.len;
        let n = self.cfg.n_heads * self.cfg.head_dim;
        let q = &self.current_q[i * n..(i + 1) * n];
        let pool = &self.pool;
        let pages = &kvl.pages;
        let k_at = |h: usize, t: usize| -> *const f32 {
            pool.k_row(pages[t / bs], h, t % bs).as_ptr()
        };
        let flat = gate::oracle_scores(&self.cfg, q, &k_at, len, bs);
        let nblk = len.div_ceil(bs);
        (0..self.cfg.n_kv_heads)
            .map(|h| flat[h * nblk..(h + 1) * nblk].to_vec())
            .collect()
    }

    /// Gather + attention executable dispatch (steps 4-5).
    fn run_attention(&mut self, l: usize, q_rope_t: &HostTensor, x_t: &HostTensor,
                     active: &[usize], selections: &[Option<Selection>])
                     -> Result<HostTensor> {
        let b = self.batch;
        let (hkv, h_all, dh) = (self.cfg.n_kv_heads, self.cfg.n_heads, self.cfg.head_dim);
        let bs = self.ecfg.block_size;
        let _ = h_all;
        let any_dense =
            active.iter().any(|&i| matches!(selections[i], Some(Selection::Dense)));
        let wo = format!("l{l}.wo");
        let w1 = format!("l{l}.w1");
        let w2 = format!("l{l}.w2");
        let ln2 = format!("l{l}.ln2");

        // Sparse staging is capped by the largest compiled variant; if a
        // selection (e.g. a low threshold) exceeds it, attending densely
        // is the correct superset behaviour.
        let mut max_tokens = 1usize;
        if !any_dense {
            for &i in active {
                let slot = self.slots[i].as_ref().unwrap();
                let kvl = &slot.kv[l];
                if let Some(Selection::Shared(v)) | Some(Selection::PerHead(v)) =
                    &selections[i]
                {
                    for row in v {
                        let t: usize = row
                            .iter()
                            .map(|&j| kvl.tokens_in_block(j as usize, bs))
                            .sum();
                        max_tokens = max_tokens.max(t);
                    }
                }
            }
        }
        let variant = self.rt.manifest.sel_variant_for(max_tokens);
        if any_dense || variant.is_err() {
            // Dense baseline: ship the full cache.
            let s = self.max_seq;
            let mut kc = vec![0f32; b * hkv * s * dh];
            let mut vc = vec![0f32; b * hkv * s * dh];
            let mut seq_len = vec![0i32; b];
            let mut touched_total = 0u64;
            for &i in active {
                let mut touched = 0u64;
                {
                    let slot = self.slots[i].as_ref().unwrap();
                    let kvl = &slot.kv[l];
                    seq_len[i] = kvl.len as i32;
                    for h in 0..hkv {
                        for (blk, &pg) in kvl.pages.iter().enumerate() {
                            if let Some(t) = &mut self.offload {
                                t.touch(pg);
                            }
                            let n = kvl.tokens_in_block(blk, bs);
                            let off = ((i * hkv + h) * s + blk * bs) * dh;
                            self.pool.gather_block(
                                pg, h, n,
                                &mut kc[off..off + n * dh],
                                &mut vc[off..off + n * dh],
                            );
                            touched += 2 * (n * dh * 4) as u64;
                        }
                    }
                }
                touched_total += touched;
                let slot = self.slots[i].as_mut().unwrap();
                slot.stats.kv_bytes_touched += touched;
            }
            self.metrics.kv_bytes_touched += touched_total;
            self.metrics.kv_bytes_dense_equiv += touched_total;
            let kc_t = HostTensor::f32(vec![b, hkv, s, dh], kc);
            let vc_t = HostTensor::f32(vec![b, hkv, s, dh], vc);
            let sl_t = HostTensor::i32(vec![b], seq_len);
            let args = [
                Arg::Host(q_rope_t),
                Arg::Host(&kc_t),
                Arg::Host(&vc_t),
                Arg::Host(&sl_t),
                Arg::Host(x_t),
                Arg::Dev(&self.dev[&wo]),
                Arg::Dev(&self.dev[&w1]),
                Arg::Dev(&self.dev[&w2]),
                Arg::Dev(&self.dev[&ln2]),
            ];
            let outs = self.rt.call("layer_post_dense", &args)?;
            return Ok(outs.into_iter().next().unwrap());
        }

        // Sparse: widest head-row in tokens -> staging variant.
        let per_head =
            active.iter().any(|&i| matches!(selections[i], Some(Selection::PerHead(_))));
        let t_cap = variant.expect("checked above");
        let heads = if per_head { h_all } else { hkv };
        let g = self.cfg.group_size;
        let mut k_sel = vec![0f32; b * heads * t_cap * dh];
        let mut v_sel = vec![0f32; b * heads * t_cap * dh];
        let mut mask = vec![0f32; b * heads * t_cap];
        let mut dense_equiv = 0u64;
        let mut touched_total = 0u64;
        for &i in active {
            let rows: Vec<Vec<i32>> = match selections[i].as_ref().unwrap() {
                Selection::Shared(v) => {
                    if per_head {
                        // Mixed Shared/PerHead batch: expand to per head.
                        let mut e = Vec::with_capacity(h_all);
                        for qh in 0..h_all {
                            e.push(v[qh / g].clone());
                        }
                        e
                    } else {
                        v.clone()
                    }
                }
                Selection::PerHead(v) => v.clone(),
                Selection::Dense => unreachable!(),
            };
            let mut touched = 0u64;
            let kvl_len = self.slots[i].as_ref().unwrap().kv[l].len;
            for (hr, row) in rows.iter().enumerate() {
                let kv_head = if per_head { hr / g } else { hr };
                let mut cursor = 0usize;
                for &j in row {
                    let (n, pg) = {
                        let slot = self.slots[i].as_ref().unwrap();
                        (slot.kv[l].tokens_in_block(j as usize, bs),
                         slot.kv[l].pages[j as usize])
                    };
                    if let Some(t) = &mut self.offload {
                        t.touch(pg);
                    }
                    let off = ((i * heads + hr) * t_cap + cursor) * dh;
                    self.pool.gather_block(
                        pg, kv_head, n,
                        &mut k_sel[off..off + n * dh],
                        &mut v_sel[off..off + n * dh],
                    );
                    let moff = (i * heads + hr) * t_cap + cursor;
                    for m in &mut mask[moff..moff + n] {
                        *m = 1.0;
                    }
                    cursor += n;
                    touched += 2 * (n * dh * 4) as u64;
                }
            }
            dense_equiv += 2 * (kvl_len * dh * 4) as u64 * hkv as u64;
            touched_total += touched;
            let slot = self.slots[i].as_mut().unwrap();
            slot.stats.kv_bytes_touched += touched;
        }
        self.metrics.kv_bytes_touched += touched_total;
        self.metrics.kv_bytes_dense_equiv += dense_equiv;
        let k_t = HostTensor::f32(vec![b, heads, t_cap, dh], k_sel);
        let v_t = HostTensor::f32(vec![b, heads, t_cap, dh], v_sel);
        let m_t = HostTensor::f32(vec![b, heads, t_cap], mask);
        let exe = if per_head {
            format!("layer_post_selh_t{t_cap}")
        } else {
            format!("layer_post_sel_t{t_cap}")
        };
        let args = [
            Arg::Host(q_rope_t),
            Arg::Host(&k_t),
            Arg::Host(&v_t),
            Arg::Host(&m_t),
            Arg::Host(x_t),
            Arg::Dev(&self.dev[&wo]),
            Arg::Dev(&self.dev[&w1]),
            Arg::Dev(&self.dev[&w2]),
            Arg::Dev(&self.dev[&ln2]),
        ];
        let outs = self.rt.call(&exe, &args)?;
        Ok(outs.into_iter().next().unwrap())
    }

    fn check_stop(&mut self, i: usize, tok: i32) {
        let max_seq = self.max_seq;
        let eos = self.vocab.eos;
        let slot = self.slots[i].as_mut().unwrap();
        if tok == eos {
            slot.stop = Some(StopReason::Eos);
        } else if slot.generated.len() >= slot.req.max_new {
            slot.stop = Some(StopReason::MaxNewTokens);
        } else if slot.len + 2 >= max_seq {
            slot.stop = Some(StopReason::ContextFull);
        }
    }

    /// Collect finished slots into completions, releasing their pages.
    fn reap(&mut self) -> Vec<Completion> {
        let mut out = Vec::new();
        for i in 0..self.batch {
            let finished = self.slots[i]
                .as_ref()
                .map(|s| s.stop.is_some())
                .unwrap_or(false);
            if finished {
                let mut slot = self.slots[i].take().unwrap();
                for kv in &mut slot.kv {
                    if let Some(t) = &mut self.offload {
                        for &pg in &kv.pages {
                            t.invalidate(pg);
                        }
                    }
                    kv.release(&mut self.pool);
                }
                let now = Instant::now();
                let ttft = slot
                    .first_token
                    .map(|t| t - slot.admitted)
                    .unwrap_or_default();
                let e2e = now - slot.admitted;
                self.metrics.record_completion(ttft, e2e, slot.generated.len());
                out.push(Completion {
                    id: slot.req.id,
                    prompt_len: slot.req.prompt.len(),
                    generated: slot.generated,
                    stop: slot.stop.unwrap(),
                    ttft,
                    e2e,
                    stats: slot.stats,
                });
            }
        }
        out
    }
}
