//! The decode engine: continuous batching over the AOT executables.
//!
//! Per decode token and layer, the engine performs the paper's inference
//! loop (Fig 3):
//!   1. `layer_pre` (device): QKV projections + RoPE + the AttnGate query.
//!   2. host: append K/V to the paged cache, pre-RoPE K to the pending
//!      K-compression block (flushing a new compressed entry every
//!      `block_size` tokens, §3.2), RoPE'd K to the Quest min/max
//!      metadata.
//!   3. host: block selection under the configured policy (§3.1) — gate
//!      top-k / threshold, oracle, Quest, or dense — with the partial
//!      last block always force-activated.
//!   4. host: gather the selected pages into the staging buffer (this is
//!      the I/O the paper saves: bytes moved scale with the budget).
//!   5. `layer_post_sel_t{T}` / `layer_post_selh_t{T}` / dense (device):
//!      block-sparse attention + the rest of the layer.
//! Then `lm_head` + sampling, once per token for the whole batch.

use std::collections::{HashMap, HashSet, VecDeque};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::arena::StagingArena;
use super::gather::{self, DenseGeom, GatherJob, SparseGeom};
use super::memory::PageGeometry;
use super::metrics::Metrics;
use super::request::{Completion, EngineEvent, Priority, QueuedReq, Request,
                     SeqStats, StopReason};
use super::sampling;
use super::DecodeEngine;
use crate::gate;
use crate::kvcache::offload::{OffloadConfig, TieredKv};
use crate::kvcache::{chain_hash, KcompCache, PageId, PagedKvPool, PrefixCache,
                     SeqKv, ROOT_HASH};
use crate::model::{ModelConfig, ParamStore};
use crate::runtime::{Arg, DeviceTensor, HostTensor, Runtime};
use crate::sparse::policy::{select_budget_into, select_threshold_into,
                            select_top_p_into, Policy, SelKind, SelectionBuf};
use crate::sparse::quest::QuestMeta;
use crate::sparse::topk::{count_hits_sorted, merge_mandatory, TopkScratch};
use crate::util::rng::Rng;
use crate::workload::Vocab;

#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    pub policy: Policy,
    /// Hybrid ablation (§5.2): this many leading layers run dense.
    pub dense_first_layers: usize,
    /// Sparse attention block size (tokens); also the KV page size.
    pub block_size: usize,
    pub max_new: usize,
    /// 0.0 = greedy.
    pub temperature: f32,
    pub seed: u64,
    /// Record gate-vs-oracle recall at every step (slow; diagnostics).
    pub track_recall: bool,
    /// KV offload simulation (§3.2): fast-tier capacity in pages
    /// (0 = disabled). Pages touched by attention gathers go through an
    /// LRU fast tier; misses are charged as slow-tier fetches.
    pub offload_fast_pages: usize,
    /// Persistent worker-pool fan-out for the per-slot gather stage:
    /// `0` = auto ([`gather::GatherPool::default_lanes`] — half the
    /// cores, capped at 4), `1` = serial, `n > 1` = exactly `n` lanes.
    /// The arena's per-row dirty extents partition staging writes
    /// disjointly by slot, so the parallel gather is bit-identical to
    /// the serial one (see `coordinator::gather`).
    pub gather_threads: usize,
    /// Use the runtime-dispatched SIMD kernels (`util::simd`) for the
    /// host hot path (default). `false` pins the **process-global**
    /// dispatch to the bit-identical scalar fallback (CLI `--no-simd`) —
    /// global because the kernels are free functions shared by every
    /// engine in the process, so mixed-mode shards are not expressible
    /// (nor useful: both modes produce identical output, only speed
    /// differs).
    pub simd: bool,
    /// Times a request may be preempted (pages dropped, requeued for
    /// re-prefill) before it is terminated with
    /// [`StopReason::ResourceExhausted`].
    pub preempt_retries: u32,
    /// Chunked prefill (continuous batching): the per-step budget of
    /// prompt tokens prefilled, shared by every half-prefilled slot.
    /// `0` = monolithic — a whole prompt per step, the pre-chunking
    /// behavior that let one long admission stall every in-flight
    /// decode. Must be a multiple of `block_size` so chunk boundaries
    /// land on kcomp gate-block (= KV page) edges and the compressed
    /// gate cache never straddles a resume point. The default, 128, is
    /// the least common multiple of the paper's 64/128 sparse block
    /// sizes (and a multiple of the default engine block size 16).
    pub prefill_chunk: usize,
    /// Content-addressed prefix KV cache: completed prompt blocks are
    /// published (KV page + kcomp gate entry + Quest metadata per layer)
    /// under their rolling chain hash, and an admission whose prompt
    /// shares a cached block-aligned prefix maps those pages instead of
    /// re-prefilling them. Pages are refcount-shared in the pool, so a
    /// cached block and the live sequences using it never copy; warm
    /// prefills are bit-identical to cold ones (the gate/Quest splice is
    /// exact, see `kvcache::kcomp` / `sparse::quest`).
    pub prefix_cache: bool,
    /// Cap on cached prefix blocks (LRU-evicted beyond); 0 = unbounded —
    /// memory pressure still evicts, see `Engine::prefix_gc`.
    pub prefix_cache_blocks: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: Policy::Dense,
            dense_first_layers: 0,
            block_size: 16,
            max_new: 32,
            temperature: 0.0,
            seed: 0,
            track_recall: false,
            offload_fast_pages: 0,
            gather_threads: 0,
            simd: true,
            preempt_retries: 3,
            prefill_chunk: 128,
            prefix_cache: false,
            prefix_cache_blocks: 0,
        }
    }
}

/// What the prefix cache stores per cached prompt block: one shared KV
/// page per layer (refcounted in the pool — the cache holds its own
/// reference) plus the per-layer compressed-gate entry and Quest min/max
/// metadata for the block, so a warm admission splices selection state
/// instead of recomputing it.
struct PrefixBlock {
    /// [n_layers] — page holding the block's K/V at every layer.
    pages: Vec<PageId>,
    /// [n_layers][hkv * d_gate] — kcomp entry rows
    /// ([`KcompCache::export_block`] format).
    kcomp: Vec<Vec<f32>>,
    /// [n_layers][hkv * 2 * head_dim] — Quest min/max rows
    /// ([`QuestMeta::export_block`] format).
    quest: Vec<Vec<f32>>,
}

/// Per-slot sequence state.
struct Slot {
    req: Request,
    admitted: Instant,
    first_token: Option<Instant>,
    /// All tokens: prompt + generated (last one not yet in KV cache).
    tokens: Vec<i32>,
    /// Tokens whose KV is cached.
    len: usize,
    kv: Vec<SeqKv>,          // per layer
    kcomp: Vec<KcompCache>,  // per layer
    quest: Vec<QuestMeta>,   // per layer
    generated: Vec<i32>,
    stats: SeqStats,
    stop: Option<StopReason>,
    /// Times this request has been preempted so far.
    retries: u32,
    /// Prefill progress: tokens of the effective prefill span already
    /// cached. While `< prefill_target` the slot is half-prefilled — it
    /// occupies a slot and holds KV pages but has not emitted its first
    /// token, does not decode, and can be cancelled/expired/preempted
    /// like any other occupant.
    prefill_pos: usize,
    /// Effective prefill span: the whole prompt for fresh requests, all
    /// but the trailing resume token for preempted ones.
    prefill_target: usize,
    /// Deepest prefix-cache chain hash this slot has pinned
    /// ([`ROOT_HASH`] while none): the blocks it adopted at admission
    /// plus every block it has published since. Unpinned on every
    /// terminal / preemption path.
    prefix_hash: u64,
    /// Length of the pinned chain, in blocks.
    prefix_blocks: usize,
}

impl Slot {
    fn prefilling(&self) -> bool {
        self.prefill_pos < self.prefill_target
    }
}

/// Stop decision after emitting `tok` into `slot` (shared by the prefill
/// first-token path and the decode path; the rule itself lives in
/// [`StopReason::decide`] so `SimEngine` applies the identical one).
fn stop_for(slot: &Slot, tok: i32, eos: i32, max_seq: usize) -> Option<StopReason> {
    StopReason::decide(tok, eos, slot.generated.len(), slot.req.max_new,
                       slot.len, max_seq)
}

pub struct Engine {
    pub rt: Rc<Runtime>,
    pub cfg: ModelConfig,
    pub ecfg: EngineConfig,
    params: ParamStore,
    pool: PagedKvPool,
    slots: Vec<Option<Slot>>,
    queue: VecDeque<QueuedReq>,
    rng: Rng,
    pub metrics: Metrics,
    pub vocab: Vocab,
    batch: usize,
    max_seq: usize,
    /// Resident device copies of every weight tensor (uploaded once).
    dev: HashMap<String, DeviceTensor>,
    /// Per-layer wk_gate host copies (hot in the kcomp update).
    wk_gates: Vec<Vec<f32>>,
    /// Current decode step's q_rope (for the oracle / recall paths);
    /// cleared and refilled per layer, capacity retained.
    current_q: Vec<f32>,
    /// Optional tiered-KV offload accounting (§3.2).
    pub offload: Option<TieredKv>,
    /// Persistent staging buffers for the gather stage — zero heap
    /// allocation per decode step once every variant has been touched.
    arena: StagingArena,
    /// Selection-stage scratch (score rows, top-k index buffer, oracle
    /// rows), reused across slots, layers, and steps.
    scratch: SelectScratch,
    /// One reusable selection per batch slot; `run_attention` borrows
    /// rows from here instead of cloning per-head index lists.
    sel_bufs: Vec<SelectionBuf>,
    /// Persistent gather fan-out lanes (`gather_threads > 1`); spawned
    /// once here instead of a scoped-thread spawn per decode step.
    gather_pool: Option<gather::GatherPool>,
    /// Ids flagged for cancellation, applied at the next step boundary
    /// (the slot's pages are freed in the reap that follows).
    cancels: HashSet<u64>,
    /// Completions synthesized off-slot (cancelled or deadline-expired
    /// while still queued), drained by the next reap.
    done_early: Vec<Completion>,
    /// Content-addressed prefix cache (`ecfg.prefix_cache`): radix index
    /// of published prompt blocks, keyed by rolling chain hash.
    prefix: Option<PrefixCache<PrefixBlock>>,
}

/// Reusable selection scratch (see `Engine::select`).
#[derive(Default)]
struct SelectScratch {
    topk: TopkScratch,
    /// Gate score rows [hkv][n_complete].
    scores: Vec<Vec<f32>>,
    /// One Quest score row (per query head, refilled in place).
    quest_row: Vec<f32>,
    /// Oracle score rows (oracle policy + recall diagnostics).
    oracle: Vec<Vec<f32>>,
    /// Oracle top-k rows (ascending) for recall accounting.
    orc: Vec<Vec<i32>>,
    /// Flat `[hkv * nblk]` score buffer + per-token logits row reused by
    /// `gate::oracle_scores_into` (the track_recall / oracle hot loop).
    oracle_flat: Vec<f32>,
    oracle_logits: Vec<f32>,
}

impl Engine {
    pub fn new(rt: Rc<Runtime>, params: ParamStore, gates: ParamStore,
               ecfg: EngineConfig) -> Result<Engine> {
        // Process-global (see the field docs), last-writer-wins: an
        // unconditional write means a later simd=true engine un-pins a
        // prior simd=false one instead of the flag sticking off.
        crate::util::simd::set_scalar(!ecfg.simd);
        let cfg = ModelConfig::from_json(&rt.manifest.model)?;
        let batch = rt.manifest.aot.get("decode_batch")?.as_usize()?;
        let max_seq = rt.manifest.aot.get("prefill_len")?.as_usize()?;
        if max_seq % ecfg.block_size != 0 {
            bail!("block size {} must divide max_seq {max_seq}", ecfg.block_size);
        }
        if ecfg.prefill_chunk % ecfg.block_size != 0 {
            bail!("prefill chunk {} must be a multiple of block size {} \
                   (kcomp gate blocks must not straddle a chunk boundary)",
                  ecfg.prefill_chunk, ecfg.block_size);
        }
        let pages_per_seq = max_seq / ecfg.block_size + 1;
        let capacity = batch * cfg.n_layers * pages_per_seq;
        let pool = PagedKvPool::new(capacity, cfg.n_kv_heads, cfg.head_dim,
                                    ecfg.block_size);
        let slots = (0..batch).map(|_| None).collect();
        let wk_gates = (0..cfg.n_layers)
            .map(|l| Ok(gates.get(&format!("l{l}.wk_gate"))?.as_f32()?.to_vec()))
            .collect::<Result<Vec<_>>>()?;
        let offload = if ecfg.offload_fast_pages > 0 {
            Some(TieredKv::new(OffloadConfig {
                fast_capacity: ecfg.offload_fast_pages,
                fetch_s_per_byte: 1e-10, // ~10 GB/s host link analog
                page_bytes: 2 * cfg.n_kv_heads * ecfg.block_size * cfg.head_dim * 4,
            }))
        } else {
            None
        };
        // Upload all weights once; the decode hot path only ships
        // activations and gathered KV.
        let mut dev = HashMap::new();
        for (spec, t) in params.specs.iter().zip(&params.tensors) {
            dev.insert(spec.name.clone(), rt.upload(t)?);
        }
        for (spec, t) in gates.specs.iter().zip(&gates.tensors) {
            dev.insert(spec.name.clone(), rt.upload(t)?);
        }
        Ok(Engine {
            rng: Rng::new(ecfg.seed),
            rt,
            cfg,
            ecfg,
            params,
            pool,
            slots,
            queue: VecDeque::new(),
            dev,
            metrics: Metrics::new(),
            vocab: Vocab::default(),
            batch,
            max_seq,
            wk_gates,
            current_q: Vec::new(),
            offload,
            arena: StagingArena::new(),
            scratch: SelectScratch::default(),
            sel_bufs: (0..batch).map(|_| SelectionBuf::new()).collect(),
            gather_pool: {
                let lanes = if ecfg.gather_threads == 0 {
                    gather::GatherPool::default_lanes()
                } else {
                    ecfg.gather_threads
                };
                (lanes > 1).then(|| gather::GatherPool::new(lanes))
            },
            cancels: HashSet::new(),
            done_early: Vec::new(),
            prefix: ecfg.prefix_cache.then(|| {
                PrefixCache::new(ecfg.block_size, ecfg.prefix_cache_blocks)
            }),
        })
    }

    /// Prompt blocks currently cached in the prefix cache.
    pub fn prefix_cached_blocks(&self) -> usize {
        self.prefix.as_ref().map(|p| p.len()).unwrap_or(0)
    }

    /// Drop every unpinned cached prefix block, releasing the cache's
    /// page references; returns the number evicted. Blocks pinned by
    /// live slots stay (their pages are shared with those slots anyway).
    pub fn prefix_evict_all(&mut self) -> usize {
        let Some(pc) = self.prefix.as_mut() else { return 0 };
        let mut evicted = Vec::new();
        let n = pc.evict_all(&mut evicted);
        for blk in evicted {
            for pg in blk.pages {
                self.pool.release(pg);
            }
        }
        self.metrics.prefix_evictions += n as u64;
        n
    }

    /// Memory-pressure GC: while pool headroom is below one step's worst
    /// case allocation, evict unpinned cached blocks (LRU leaves) before
    /// any live slot could starve. With every unpinned block evicted the
    /// pool is back to its no-cache worst case — which the pool is sized
    /// for — so cached pages can never make an admission or decode
    /// append fail. Runs every step; a no-op without the prefix cache.
    fn prefix_gc(&mut self) {
        if self.prefix.is_none() {
            return;
        }
        let chunk_pages = if self.ecfg.prefill_chunk == 0 {
            self.max_seq / self.ecfg.block_size
        } else {
            self.ecfg.prefill_chunk / self.ecfg.block_size
        };
        // Per step, each slot appends <= 1 decode page per layer and the
        // prefill chunk spans <= chunk_pages (+1 partial per slot).
        let margin = self.cfg.n_layers * (2 * self.batch + chunk_pages + 1);
        while self.pool.free_pages() < margin {
            let Some(pc) = self.prefix.as_mut() else { return };
            let Some(blk) = pc.evict_one() else { return };
            for pg in blk.pages {
                self.pool.release(pg);
            }
            self.metrics.prefix_evictions += 1;
        }
    }

    /// Staging buffer-set creations so far (constant in steady state —
    /// exposed for allocation-regression tests).
    pub fn arena_allocations(&self) -> usize {
        self.arena.allocations()
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Free pages in the KV pool (leak detection in tests).
    pub fn pool_free(&self) -> usize {
        self.pool.free_pages()
    }

    pub fn pool_capacity(&self) -> usize {
        self.pool.capacity()
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn submit(&mut self, req: Request) {
        self.submit_at(req, Instant::now());
    }

    /// Enqueue with an externally observed arrival instant (the shard
    /// router passes its own timestamp so channel dwell counts toward
    /// TTFT/e2e).
    pub fn submit_at(&mut self, req: Request, arrived: Instant) {
        self.submit_queued(QueuedReq::fresh(req, arrived));
    }

    /// Enqueue a queued-request record, preserving resume state (partial
    /// generation from a preemption, original arrival, first-token
    /// instant, retry count).
    pub fn submit_queued(&mut self, q: QueuedReq) {
        // Guard on the *effective* prefill span, not the prompt alone:
        // re-admission stages `prompt ++ resume[..k-1]` (the trailing
        // resume token plays the sampled-first-token role), so a request
        // preempted near the context limit carries resume tokens that
        // count against the staged span.
        let eff = q.req.prompt.len() + q.resume.len().saturating_sub(1);
        assert!(eff + 2 < self.max_seq,
                "effective prefill of {eff} tokens (prompt {} + resume {}) \
                 too long for context {}",
                q.req.prompt.len(), q.resume.len(), self.max_seq);
        self.metrics.start_clock();
        self.queue.push_back(q);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn idle(&self) -> bool {
        // Off-slot completions still owed count as work: a step must run
        // to emit them.
        self.queue.is_empty() && self.active() == 0 && self.done_early.is_empty()
    }

    /// Run everything currently queued to completion.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.idle() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    /// One engine iteration: at most one prefill chunk (admitting waiting
    /// requests into free slots) *and* one decode token for the batch
    /// that was already running.
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        self.step_core(&mut |ev| {
            if let EngineEvent::Finished(c) = ev {
                out.push(c);
            }
        })?;
        Ok(out)
    }

    /// One engine iteration over the event sink — shared by `step` and
    /// `step_events`, and the control-flow mirror of `SimEngine`'s
    /// `step_core`: control stops (cancel / deadline, the shared
    /// [`StopReason::control`] rule), an immediate reap so a stopped
    /// slot's KV pages are freed *this* step, then at most one prefill
    /// chunk *and* a decode step for the already-running batch, then the
    /// regular reap. Admission never suppresses decode: a long prompt is
    /// prefilled `prefill_chunk` tokens per step while in-flight decodes
    /// keep producing tokens, which is what bounds ITL under a mixed
    /// long-prompt + short-decode trace.
    fn step_core(&mut self, sink: &mut dyn FnMut(EngineEvent)) -> Result<()> {
        self.apply_control_stops();
        self.reap_into(sink);
        // Yield cached prefix pages back under memory pressure *before*
        // any admission or append could contend for them.
        self.prefix_gc();
        // Priority preemption: a strictly-higher-priority request waiting
        // in the queue evicts the weakest occupant of a full batch at
        // this step boundary (its pages released through the same reap
        // path cancellation uses).
        self.preempt_for_priority(sink);
        self.reap_into(sink);
        // Decode-eligible set snapshotted *before* this step's prefill
        // chunk: a slot whose prefill completes this step takes its first
        // token from the prefill logits and joins decode next step.
        let decode_set: Vec<usize> = (0..self.batch)
            .filter(|&i| {
                self.slots[i]
                    .as_ref()
                    .map(|s| !s.prefilling() && s.stop.is_none())
                    .unwrap_or(false)
            })
            .collect();
        self.admit_and_prefill(sink)?;
        if !decode_set.is_empty() {
            self.decode_step(sink, &decode_set)?;
        }
        self.reap_into(sink);
        Ok(())
    }

    /// Evict the weakest active request (lowest priority, youngest on
    /// ties) when the batch is full and the queue holds a strictly
    /// higher-priority request. The victim's pages are dropped and it
    /// requeues at the front carrying its partial generation for
    /// re-prefill; a victim whose retry budget is spent is terminated
    /// with [`StopReason::ResourceExhausted`] instead (through the same
    /// reap path, so its pages free identically).
    fn preempt_for_priority(&mut self, sink: &mut dyn FnMut(EngineEvent)) {
        let Some(best) = self.queue.iter().map(|q| q.req.priority).max() else {
            return;
        };
        if self.slots.iter().any(|s| s.is_none()) {
            return; // a free slot admits without eviction
        }
        let mut victim: Option<usize> = None;
        for i in 0..self.batch {
            let Some(c) = self.slots[i].as_ref() else { continue };
            if c.stop.is_some() {
                return; // a slot is already freeing this step
            }
            victim = Some(match victim {
                None => i,
                Some(v) => {
                    let cur = self.slots[v].as_ref().unwrap();
                    if c.req.priority < cur.req.priority
                        || (c.req.priority == cur.req.priority
                            && c.admitted >= cur.admitted)
                    {
                        i
                    } else {
                        v
                    }
                }
            });
        }
        let Some(v) = victim else { return };
        if self.slots[v].as_ref().unwrap().req.priority >= best {
            return; // never evict an equal-or-higher-priority occupant
        }
        let mut slot = self.slots[v].take().unwrap();
        if slot.retries >= self.ecfg.preempt_retries {
            // Retry budget spent: terminal, pages freed by the reap.
            slot.stop = Some(StopReason::ResourceExhausted);
            self.slots[v] = Some(slot);
            return;
        }
        if slot.prefilling() {
            // Half-prefilled victim: drop its staging resume cursor so
            // the row is reclaimed on the next prefill acquire. Its
            // `generated` still holds exactly the resume tokens it was
            // admitted with (nothing is emitted mid-prefill), so the
            // requeue below carries the correct replay state.
            self.arena.abort_prefill_row(v);
        }
        self.release_slot_resources(&mut slot);
        self.metrics.requests_preempted += 1;
        sink(EngineEvent::Preempted { id: slot.req.id });
        self.queue.push_front(QueuedReq {
            req: slot.req,
            arrived: slot.admitted,
            resume: slot.generated,
            first_token_at: slot.first_token,
            retries: slot.retries + 1,
            sticky: false,
        });
    }

    /// Remove the best queued request: highest priority, front-most
    /// (oldest) among equals.
    fn pop_best_queued(&mut self) -> Option<QueuedReq> {
        let mut best: Option<usize> = None;
        for (j, q) in self.queue.iter().enumerate() {
            best = Some(match best {
                None => j,
                Some(b) if q.req.priority > self.queue[b].req.priority => j,
                Some(b) => b,
            });
        }
        best.and_then(|j| self.queue.remove(j))
    }

    /// Flag request `id` for cancellation; `true` iff this engine owns it
    /// (queued or mid-decode). Applied at the next step boundary.
    pub fn cancel(&mut self, id: u64) -> bool {
        let known = self
            .slots
            .iter()
            .flatten()
            .any(|s| s.stop.is_none() && s.req.id == id)
            || self.queue.iter().any(|q| q.req.id == id);
        if known {
            self.cancels.insert(id);
        }
        known
    }

    /// Step-boundary control stops (shared rule: [`StopReason::control`]):
    /// flag cancelled / deadline-expired active slots for the reap that
    /// follows, and complete cancelled or expired requests still waiting
    /// in the queue (shared code: [`request::expire_queued`]) without
    /// ever occupying a slot.
    ///
    /// [`request::expire_queued`]: super::request::expire_queued
    fn apply_control_stops(&mut self) {
        let now = Instant::now();
        for slot in self.slots.iter_mut().flatten() {
            if slot.stop.is_none() {
                let cancelled = self.cancels.remove(&slot.req.id);
                if let Some(stop) =
                    StopReason::control(cancelled, slot.req.deadline, now)
                {
                    slot.stop = Some(stop);
                }
            }
        }
        super::request::expire_queued(&mut self.queue, &mut self.cancels,
                                      &mut self.done_early, now);
    }

    // ------------------------------------------------------------------
    // Prefill
    // ------------------------------------------------------------------

    /// Admission plus at most one prefill chunk. Free slots are filled
    /// from the queue (each new occupant starts half-prefilled at
    /// position 0), then a shared budget of `prefill_chunk` tokens
    /// (unbounded when 0) advances half-prefilled slots in slot order
    /// through a single padded `prefill` call. The staged span is
    /// *resumable*: mid-chunk rows keep their token prefix in the arena
    /// (`PrefillStaging` cursor), so each step only writes the new span
    /// and the device call re-covers the prefix (our AOT prefill has no
    /// KV-prefix input; recompute is the price of a fixed executable
    /// set — see PERF.md "Chunked prefill"). Rows already cached from
    /// earlier chunks are not re-scattered, so KV/page state and the
    /// final logits row are bit-identical to a monolithic prefill.
    ///
    /// A slot whose cursor reaches its target on this chunk samples its
    /// first token from the chunk's logits (or, on resume replay, keeps
    /// the trailing resume token) — TTFT semantics are unchanged: the
    /// clock stops when the first token exists, and a chunked prefill
    /// simply reaches that point a few steps later while decode keeps
    /// running.
    fn admit_and_prefill(&mut self,
                         sink: &mut dyn FnMut(EngineEvent)) -> Result<()> {
        for i in 0..self.batch {
            if self.slots[i].is_none() {
                if let Some(q) = self.pop_best_queued() {
                    let QueuedReq { req, arrived, resume, first_token_at,
                                    retries, .. } = q;
                    // Resume replay: the effective prefill input is
                    // prompt ++ resume[..k-1]; the last resume token
                    // plays the sampled-first-token role on completion.
                    let mut tokens = req.prompt.clone();
                    tokens.extend_from_slice(&resume);
                    let target = tokens.len() - usize::from(!resume.is_empty());
                    let mut kv: Vec<SeqKv> =
                        (0..self.cfg.n_layers).map(|_| SeqKv::new()).collect();
                    let mut kcomp: Vec<KcompCache> = (0..self.cfg.n_layers)
                        .map(|_| KcompCache::with_max_seq(
                            &self.cfg, self.ecfg.block_size, self.max_seq))
                        .collect();
                    let mut quest: Vec<QuestMeta> = (0..self.cfg.n_layers)
                        .map(|_| QuestMeta::new(&self.cfg, self.ecfg.block_size,
                                                self.max_seq))
                        .collect();
                    // Prefix-cache lookup: adopt the longest cached
                    // block-aligned prompt prefix — shared pages are
                    // retained (never copied), gate entries and Quest
                    // metadata spliced — and start the chunked prefill
                    // at the first uncached block. Reuse is capped one
                    // block short of the effective span so the first
                    // token still samples through the normal prefill
                    // logits path.
                    let bs = self.ecfg.block_size;
                    let mut prefix_hash = ROOT_HASH;
                    let mut prefix_blocks = 0usize;
                    if let Some(pc) = self.prefix.as_mut() {
                        let hit = pc.lookup(&req.prompt);
                        let mut r = hit.blocks;
                        while r > 0 && r * bs >= target {
                            r -= 1;
                        }
                        if r > 0 {
                            let hash = pc.ancestor(hit.hash, hit.blocks - r);
                            pc.pin(hash, r);
                            for blk in pc.chain_payloads(hash, r) {
                                for l in 0..self.cfg.n_layers {
                                    let pg = blk.pages[l];
                                    self.pool.retain(pg);
                                    kv[l].pages.push(pg);
                                    kv[l].len += bs;
                                    kcomp[l].adopt_block(&blk.kcomp[l]);
                                    quest[l].adopt_block(&blk.quest[l]);
                                }
                            }
                            prefix_hash = hash;
                            prefix_blocks = r;
                            self.metrics.prefix_hits += 1;
                            self.metrics.prefix_blocks_reused += r as u64;
                        }
                    }
                    self.slots[i] = Some(Slot {
                        tokens,
                        len: prefix_blocks * bs,
                        kv,
                        kcomp,
                        quest,
                        generated: resume,
                        stats: SeqStats::default(),
                        stop: None,
                        req,
                        admitted: arrived,
                        first_token: first_token_at,
                        retries,
                        prefill_pos: prefix_blocks * bs,
                        prefill_target: target,
                        prefix_hash,
                        prefix_blocks,
                    });
                }
            }
        }
        let work: Vec<usize> = (0..self.batch)
            .filter(|&i| {
                self.slots[i].as_ref().map(|s| s.prefilling()).unwrap_or(false)
            })
            .collect();
        if work.is_empty() {
            return Ok(());
        }
        let t0 = Instant::now();
        let (b, s) = (self.batch, self.max_seq);
        let Engine { arena, slots, params, dev, rt, pool, cfg, ecfg, wk_gates,
                     rng, metrics, vocab, prefix, .. } = self;
        let (hkv, dh, l_n) = (cfg.n_kv_heads, cfg.head_dim, cfg.n_layers);
        let nvocab = cfg.vocab;
        let mut budget = if ecfg.prefill_chunk == 0 {
            usize::MAX
        } else {
            ecfg.prefill_chunk
        };
        // Padded prefill batch staged through the persistent arena set:
        // acquire dirty-clears finished rows but keeps mid-chunk spans.
        let set = arena.prefill(b, s, hkv * dh);
        // Spans advanced this chunk: (slot, from, to).
        let mut spans: Vec<(usize, usize, usize)> = Vec::new();
        {
            let (ids, seq_len, dirty, cursor) = set.chunk_mut();
            for &i in &work {
                if budget == 0 {
                    break; // chunk spent; this slot resumes next step
                }
                let slot = slots[i].as_ref().unwrap();
                let (pos, target) = (slot.prefill_pos, slot.prefill_target);
                // A warm admission starts its cursor at 0 but its scatter
                // position at the reused-prefix end: the device prefill
                // has no KV-prefix input, so the adopted span's *ids*
                // must still be staged (and recomputed) even though its
                // KV is mapped from the cache and never re-scattered.
                debug_assert!(cursor[i] <= pos,
                              "staging cursor tracks slot progress");
                let cur = cursor[i];
                let end = target.min(pos + budget);
                ids[i * s + cur..i * s + end]
                    .copy_from_slice(&slot.tokens[cur..end]);
                seq_len[i] = end as i32;
                dirty[i] = end;
                // The cursor stays nonzero (span persists across
                // acquires) until the slot's prefill completes.
                cursor[i] = if end == target { 0 } else { end };
                budget -= end - pos;
                spans.push((i, pos, end));
            }
        }
        let outs = {
            let mut args: Vec<Arg> = Vec::with_capacity(params.specs.len() + 2);
            for sp in &params.specs {
                args.push(Arg::Dev(&dev[sp.name.as_str()]));
            }
            args.push(Arg::Host(&set.ids));
            args.push(Arg::Host(&set.seq_len));
            rt.call("prefill", &args)?
        };
        let lg = outs[0].as_f32()?;
        let kr = outs[1].as_f32()?;
        let vv = outs[2].as_f32()?;
        let kp = outs[3].as_f32()?;
        // cache layout [L, B, Hkv, S, dh]
        let idx = |l: usize, bi: usize, h: usize, t: usize| {
            (((l * b + bi) * hkv + h) * s + t) * dh
        };
        // Pre-reserved per-token scatter rows (arena-owned, not per-call).
        let (krow, vrow, prow) = set.rows_mut();
        let mut chunk_tokens = 0u64;
        for &(i, pos, end) in &spans {
            // Scatter only the newly covered span; rows before `pos` are
            // already in the paged cache from earlier chunks.
            for t in pos..end {
                for l in 0..l_n {
                    for h in 0..hkv {
                        let o = idx(l, i, h, t);
                        krow[h * dh..(h + 1) * dh].copy_from_slice(&kr[o..o + dh]);
                        vrow[h * dh..(h + 1) * dh].copy_from_slice(&vv[o..o + dh]);
                        prow[h * dh..(h + 1) * dh].copy_from_slice(&kp[o..o + dh]);
                    }
                    let slot = slots[i].as_mut().unwrap();
                    slot.kv[l].append(pool, krow, vrow)?;
                    slot.quest[l].append(krow);
                    slot.kcomp[l].append(cfg, &wk_gates[l], prow);
                }
            }
            chunk_tokens += (end - pos) as u64;
            let slot = slots[i].as_mut().unwrap();
            slot.prefill_pos = end;
            slot.len = end;
            // Publish freshly completed full *prompt* blocks into the
            // prefix cache, extending this slot's pinned chain (parents
            // are pinned, so cap-eviction can never break the chain
            // mid-publish). Pages gain the cache's own reference; gate /
            // Quest state is exported at the block boundary, where the
            // splice is exact.
            if let Some(pc) = prefix.as_mut() {
                let bs = ecfg.block_size;
                let upto = (end / bs).min(slot.req.prompt.len() / bs);
                let mut evicted: Vec<PrefixBlock> = Vec::new();
                for jb in slot.prefix_blocks..upto {
                    let next = chain_hash(slot.prefix_hash,
                                          &slot.tokens[jb * bs..(jb + 1) * bs]);
                    if pc.payload(next).is_some() {
                        // A sibling slot published this block first:
                        // share its copy, pin it for this sequence.
                        pc.pin(next, 1);
                    } else {
                        let mut blk = PrefixBlock {
                            pages: Vec::with_capacity(l_n),
                            kcomp: Vec::with_capacity(l_n),
                            quest: Vec::with_capacity(l_n),
                        };
                        for l in 0..l_n {
                            let pg = slot.kv[l].pages[jb];
                            pool.retain(pg); // the cache's reference
                            blk.pages.push(pg);
                            let mut kc = vec![0.0; hkv * cfg.d_gate];
                            slot.kcomp[l].export_block(jb, &mut kc);
                            blk.kcomp.push(kc);
                            let mut qm = vec![0.0; hkv * 2 * dh];
                            slot.quest[l].export_block(jb, &mut qm);
                            blk.quest.push(qm);
                        }
                        let ok = pc.insert(slot.prefix_hash, next, blk,
                                           &mut evicted);
                        debug_assert!(ok, "single-threaded publish races");
                    }
                    slot.prefix_hash = next;
                    slot.prefix_blocks += 1;
                }
                for blk in evicted {
                    for pg in blk.pages {
                        pool.release(pg);
                    }
                    metrics.prefix_evictions += 1;
                }
            }
            if end < slot.prefill_target {
                continue; // still half-prefilled; no first token yet
            }
            let plen = end;
            if !slot.generated.is_empty() {
                // Resume replay: the trailing resume token already sits
                // in `tokens`/`generated`; with greedy decoding the
                // logits at plen-1 would reproduce it exactly, so no
                // sampling and — crucially — no re-emitted events
                // (indices 0..k-1 reached the client before the
                // preemption; decode continues at index k).
                let tok = *slot.tokens.last().unwrap();
                if let Some(stop) = stop_for(slot, tok, vocab.eos, s) {
                    slot.stop = Some(stop);
                }
                continue;
            }
            // First generated token from logits[i, plen-1].
            let row = &lg[(i * s + plen - 1) * nvocab..(i * s + plen) * nvocab];
            let tok = sampling::sample(row, ecfg.temperature, rng);
            slot.tokens.push(tok);
            slot.generated.push(tok);
            slot.first_token = Some(Instant::now());
            if let Some(stop) = stop_for(slot, tok, vocab.eos, s) {
                slot.stop = Some(stop);
            }
            let id = slot.req.id;
            sink(EngineEvent::Started { id });
            sink(EngineEvent::Token { id, tok, index: 0 });
        }
        metrics.prefill_chunks += 1;
        metrics.prefill_tokens += chunk_tokens;
        metrics.pages_peak =
            metrics.pages_peak.max(pool.capacity() - pool.free_pages());
        metrics.prefill_s.push(t0.elapsed().as_secs_f64());
        Ok(())
    }

    // ------------------------------------------------------------------
    // Decode
    // ------------------------------------------------------------------

    /// One decode token for `active` — the slots that had completed
    /// prefill before this step's chunk ran (half-prefilled slots and
    /// slots that sampled their first token this very step are excluded
    /// by the `step_core` snapshot).
    fn decode_step(&mut self, sink: &mut dyn FnMut(EngineEvent),
                   active: &[usize]) -> Result<()> {
        let t0 = Instant::now();
        let (b, d) = (self.batch, self.cfg.d_model);
        let (hkv, _h_all, dh, dg) = (self.cfg.n_kv_heads, self.cfg.n_heads,
                                    self.cfg.head_dim, self.cfg.d_gate);
        // Embed current tokens (host: one row copy per sequence).
        let mut x = vec![0f32; b * d];
        let mut pos = vec![0i32; b];
        {
            let emb = self.params.get("emb")?.as_f32()?;
            for &i in active {
                let slot = self.slots[i].as_ref().unwrap();
                let tok = *slot.tokens.last().unwrap() as usize;
                x[i * d..(i + 1) * d].copy_from_slice(&emb[tok * d..(tok + 1) * d]);
                pos[i] = slot.len as i32;
            }
        }
        let mut x_t = HostTensor::f32(vec![b, d], x);
        let pos_t = HostTensor::i32(vec![b], pos);

        for l in 0..self.cfg.n_layers {
            // 1. layer_pre
            let outs = {
                let args = [
                    Arg::Host(&x_t),
                    Arg::Host(&pos_t),
                    Arg::Dev(&self.dev[&format!("l{l}.wq")]),
                    Arg::Dev(&self.dev[&format!("l{l}.wk")]),
                    Arg::Dev(&self.dev[&format!("l{l}.wv")]),
                    Arg::Dev(&self.dev[&format!("l{l}.ln1")]),
                    Arg::Dev(&self.dev[&format!("l{l}.wq_gate")]),
                ];
                self.rt.call("layer_pre", &args)?
            };
            let k_rope = outs[1].as_f32()?;
            let v_new = outs[2].as_f32()?;
            let k_pre = outs[3].as_f32()?;
            let q_gate_all = outs[4].as_f32()?;
            self.current_q.clear();
            self.current_q.extend_from_slice(outs[0].as_f32()?);

            // 2. cache updates
            for &i in active {
                let krow = &k_rope[i * hkv * dh..(i + 1) * hkv * dh];
                let vrow = &v_new[i * hkv * dh..(i + 1) * hkv * dh];
                let prow = &k_pre[i * hkv * dh..(i + 1) * hkv * dh];
                let slot = self.slots[i].as_mut().unwrap();
                slot.kv[l].append(&mut self.pool, krow, vrow)?;
                slot.quest[l].append(krow);
                slot.kcomp[l].append(&self.cfg, &self.wk_gates[l], prow);
            }

            // 3. selection (into the per-slot reusable buffers)
            let effective = if l < self.ecfg.dense_first_layers {
                Policy::Dense
            } else {
                self.ecfg.policy
            };
            for &i in active {
                let qg = &q_gate_all[i * hkv * dg..(i + 1) * hkv * dg];
                self.select(i, l, effective, qg)?;
                if l == 0 {
                    self.record_activation(i, l);
                }
            }

            // 4+5. gather + attention
            x_t = self.run_attention(l, &outs[0], &x_t, active)?;
        }

        // lm_head + sampling
        let logits = {
            let args = [
                Arg::Host(&x_t),
                Arg::Dev(&self.dev["ln_f"]),
                Arg::Dev(&self.dev["head"]),
            ];
            self.rt.call("lm_head", &args)?
        };
        let lg = logits[0].as_f32()?;
        let vocab = self.cfg.vocab;
        for &i in active {
            let row = &lg[i * vocab..(i + 1) * vocab];
            let tok = sampling::sample(row, self.ecfg.temperature, &mut self.rng);
            let slot = self.slots[i].as_mut().unwrap();
            slot.len += 1;
            slot.tokens.push(tok);
            slot.generated.push(tok);
            let id = slot.req.id;
            let index = slot.generated.len() - 1;
            self.check_stop(i, tok);
            sink(EngineEvent::Token { id, tok, index });
        }
        self.metrics.pages_peak = self
            .metrics
            .pages_peak
            .max(self.pool.capacity() - self.pool.free_pages());
        self.metrics.decode_step_s.push(t0.elapsed().as_secs_f64());
        Ok(())
    }

    /// Fig 9a accounting: activated tokens per head at layer 0.
    fn record_activation(&mut self, i: usize, l: usize) {
        let bs = self.ecfg.block_size;
        let Engine { slots, sel_bufs, .. } = self;
        let slot = slots[i].as_mut().unwrap();
        let ctx = slot.kv[l].len;
        let buf = &sel_bufs[i];
        let act = match buf.kind() {
            SelKind::Dense => ctx as f64,
            SelKind::Shared | SelKind::PerHead => {
                let v = buf.rows();
                let per: f64 = v
                    .iter()
                    .map(|row| {
                        row.iter()
                            .map(|&j| slot.kv[l].tokens_in_block(j as usize, bs))
                            .sum::<usize>() as f64
                    })
                    .sum();
                per / v.len().max(1) as f64
            }
        };
        slot.stats.activated.push((ctx, act));
    }

    /// Block selection for one slot at one layer (step 3), written into
    /// the slot's persistent `SelectionBuf`. Scores, top-k indices, and
    /// selection rows all land in reused buffers: steady-state selection
    /// performs no heap allocation.
    fn select(&mut self, i: usize, l: usize, policy: Policy,
              q_gate: &[f32]) -> Result<()> {
        let bs = self.ecfg.block_size;
        let track = self.ecfg.track_recall;
        // Field-level borrow split: scratch and the slot's selection buf
        // are written while the slot caches are read.
        let Engine { slots, pool, cfg, scratch, sel_bufs, current_q, .. } = self;
        let slot = slots[i].as_ref().unwrap();
        let kc = &slot.kcomp[l];
        let buf = &mut sel_bufs[i];
        let (partial, n_complete) =
            (if kc.has_partial() { Some(kc.partial_index()) } else { None },
             kc.n_complete());
        match policy {
            Policy::Dense => buf.set_dense(),
            Policy::GateBudget { budget_tokens } => {
                kc.score_into(q_gate, &mut scratch.scores);
                let k = Policy::block_budget(budget_tokens, bs);
                select_budget_into(&scratch.scores, k, partial,
                                   &mut scratch.topk, buf);
            }
            Policy::GateThreshold { threshold } => {
                kc.score_into(q_gate, &mut scratch.scores);
                for row in &mut scratch.scores {
                    let n = row.len();
                    if n > 0 {
                        gate::softmax_rows(row, n);
                    }
                }
                select_threshold_into(&scratch.scores, threshold, partial, buf);
            }
            Policy::GateTopP { p } => {
                kc.score_into(q_gate, &mut scratch.scores);
                for row in &mut scratch.scores {
                    let n = row.len();
                    if n > 0 {
                        gate::softmax_rows(row, n);
                    }
                }
                select_top_p_into(&scratch.scores, p, partial,
                                  &mut scratch.topk, buf);
            }
            Policy::Oracle { budget_tokens } => {
                Self::oracle_rows_into(cfg, pool, current_q, slot, l, i, bs,
                                       &mut scratch.oracle_flat,
                                       &mut scratch.oracle_logits,
                                       &mut scratch.oracle);
                let k = Policy::block_budget(budget_tokens, bs);
                let take = if partial.is_some() { k.saturating_sub(1) } else { k };
                buf.begin(SelKind::Shared, cfg.n_kv_heads);
                for (h, row) in scratch.oracle.iter().enumerate() {
                    let sel = buf.row_mut(h);
                    scratch.topk.topk_into(&row[..n_complete.min(row.len())],
                                           take, sel);
                    if let Some(p) = partial {
                        merge_mandatory(sel, p);
                    }
                }
            }
            Policy::Quest { budget_tokens } => {
                let k = Policy::block_budget(budget_tokens, bs);
                let take = if partial.is_some() { k.saturating_sub(1) } else { k };
                let g = cfg.group_size;
                let dh = cfg.head_dim;
                buf.begin(SelKind::PerHead, cfg.n_heads);
                for qh in 0..cfg.n_heads {
                    let kvh = qh / g;
                    let q = &current_q[(i * cfg.n_heads + qh) * dh..][..dh];
                    slot.quest[l].scores_into(kvh, q, &mut scratch.quest_row);
                    let sel = buf.row_mut(qh);
                    let n = n_complete.min(scratch.quest_row.len());
                    scratch.topk.topk_into(&scratch.quest_row[..n], take, sel);
                    if let Some(p) = partial {
                        merge_mandatory(sel, p);
                    }
                }
            }
        }
        // Recall diagnostics vs the oracle. Oracle rows come out of
        // `topk_into` ascending, so membership is a binary search —
        // O(k log k) per head instead of the old O(k²) contains scan.
        let mut recall: Option<(f64, u64)> = None;
        if track {
            if let Policy::GateBudget { budget_tokens }
            | Policy::Quest { budget_tokens } = policy
            {
                Self::oracle_rows_into(cfg, pool, current_q, slot, l, i, bs,
                                       &mut scratch.oracle_flat,
                                       &mut scratch.oracle_logits,
                                       &mut scratch.oracle);
                let k = Policy::block_budget(budget_tokens, bs);
                let hkv = cfg.n_kv_heads;
                crate::util::buf::resize_rows(&mut scratch.orc, hkv);
                for (h, row) in scratch.oracle.iter().enumerate() {
                    scratch.topk.topk_into(&row[..n_complete.min(row.len())], k,
                                           &mut scratch.orc[h]);
                }
                let mut rsum = 0.0;
                let mut rn = 0u64;
                let g = cfg.group_size;
                match buf.kind() {
                    SelKind::Shared => {
                        for (hh, row) in buf.rows().iter().enumerate() {
                            let o = &scratch.orc[hh];
                            if !o.is_empty() {
                                let hit = count_hits_sorted(row, o);
                                rsum += hit as f64 / o.len() as f64;
                                rn += 1;
                            }
                        }
                    }
                    SelKind::PerHead => {
                        for (qh, row) in buf.rows().iter().enumerate() {
                            let o = &scratch.orc[qh / g];
                            if !o.is_empty() {
                                let hit = count_hits_sorted(row, o);
                                rsum += hit as f64 / o.len() as f64;
                                rn += 1;
                            }
                        }
                    }
                    SelKind::Dense => {}
                }
                recall = Some((rsum, rn));
            }
        }
        if let Some((rsum, rn)) = recall {
            let slot = slots[i].as_mut().unwrap();
            slot.stats.recall_sum += rsum;
            slot.stats.recall_n += rn;
        }
        Ok(())
    }

    /// Oracle block scores (true attention over the cached keys, §4.2)
    /// for one slot+layer into reusable per-KV-head rows over all blocks
    /// (incl. partial). `flat` and `logits` are the caller's reused
    /// scoring buffers (`gate::oracle_scores_into`), so the recall /
    /// oracle hot loop allocates nothing at steady state.
    #[allow(clippy::too_many_arguments)]
    fn oracle_rows_into(cfg: &ModelConfig, pool: &PagedKvPool, current_q: &[f32],
                        slot: &Slot, l: usize, i: usize, bs: usize,
                        flat: &mut Vec<f32>, logits: &mut Vec<f32>,
                        out: &mut Vec<Vec<f32>>) {
        let kvl = &slot.kv[l];
        let len = kvl.len;
        let n = cfg.n_heads * cfg.head_dim;
        let q = &current_q[i * n..(i + 1) * n];
        let pages = &kvl.pages;
        let k_at = |h: usize, t: usize| -> *const f32 {
            pool.k_row(pages[t / bs], h, t % bs).as_ptr()
        };
        gate::oracle_scores_into(cfg, q, &k_at, len, bs, flat, logits);
        let nblk = len.div_ceil(bs);
        crate::util::buf::resize_rows(out, cfg.n_kv_heads);
        for (h, row) in out.iter_mut().enumerate() {
            row.extend_from_slice(&flat[h * nblk..(h + 1) * nblk]);
        }
    }

    /// Gather + attention executable dispatch (steps 4-5).
    ///
    /// Staging goes through the persistent [`StagingArena`]: buffers are
    /// created once per compiled variant and dirty-cleared on reuse, so a
    /// steady-state decode step performs zero heap allocation here, and
    /// clearing cost scales with the previous step's selection, not the
    /// staging capacity. Selection rows are borrowed from the per-slot
    /// `SelectionBuf`s — never cloned, including the mixed
    /// Shared/PerHead batch case, which now indexes the GQA group's
    /// shared row directly instead of materialising an expanded copy.
    fn run_attention(&mut self, l: usize, q_rope_t: &HostTensor, x_t: &HostTensor,
                     active: &[usize]) -> Result<HostTensor> {
        let b = self.batch;
        let s = self.max_seq;
        let (hkv, h_all, dh) =
            (self.cfg.n_kv_heads, self.cfg.n_heads, self.cfg.head_dim);
        let g = self.cfg.group_size;
        let bs = self.ecfg.block_size;
        let wo = format!("l{l}.wo");
        let w1 = format!("l{l}.w1");
        let w2 = format!("l{l}.w2");
        let ln2 = format!("l{l}.ln2");

        let Engine { slots, pool, offload, metrics, arena, sel_bufs, rt, dev,
                     gather_pool, .. } = self;
        // Fan the per-slot gather out over the persistent pool lanes only
        // when configured and there is more than one slot to partition.
        let par = if active.len() > 1 { gather_pool.as_ref() } else { None };
        // Jobs are produced on demand by index (ascending `active` order),
        // so neither gather branch builds a per-call work list.
        let job_at = |idx: usize| {
            let i = active[idx];
            GatherJob {
                row: i,
                kv: &slots[i].as_ref().unwrap().kv[l],
                sel: &sel_bufs[i],
            }
        };
        let any_dense =
            active.iter().any(|&i| sel_bufs[i].kind() == SelKind::Dense);

        // Sparse staging is capped by the largest compiled variant; if a
        // selection (e.g. a low threshold) exceeds it, attending densely
        // is the correct superset behaviour.
        let mut max_tokens = 1usize;
        if !any_dense {
            for &i in active {
                let slot = slots[i].as_ref().unwrap();
                let kvl = &slot.kv[l];
                for row in sel_bufs[i].rows() {
                    let t: usize = row
                        .iter()
                        .map(|&j| kvl.tokens_in_block(j as usize, bs))
                        .sum();
                    max_tokens = max_tokens.max(t);
                }
            }
        }
        let variant = rt.manifest.sel_variant_for(max_tokens);
        if any_dense || variant.is_err() {
            // Dense baseline: ship the full cache.
            let set = arena.dense(b, hkv, s, dh);
            let geom = DenseGeom { hkv, block_size: bs, max_seq: s, dh };
            if let Some(t) = offload.as_mut() {
                for &i in active {
                    let kvl = &slots[i].as_ref().unwrap().kv[l];
                    for _h in 0..hkv {
                        for &pg in &kvl.pages {
                            t.touch(pg);
                        }
                    }
                }
            }
            {
                let (kc, vc, seq_len, dirty) = set.parts_mut();
                gather::gather_dense_into(pool, active.len(), &job_at, &geom,
                                          kc, vc, seq_len, dirty, par);
            }
            // I/O accounting straight from the staged dirty extents.
            let mut touched_total = 0u64;
            for &i in active {
                let staged: usize = set.dirty()[i * hkv..(i + 1) * hkv].iter().sum();
                let touched = 2 * (staged * dh * 4) as u64;
                slots[i].as_mut().unwrap().stats.kv_bytes_touched += touched;
                touched_total += touched;
            }
            metrics.kv_bytes_touched += touched_total;
            metrics.kv_bytes_dense_equiv += touched_total;
            let args = [
                Arg::Host(q_rope_t),
                Arg::Host(&set.k),
                Arg::Host(&set.v),
                Arg::Host(&set.seq_len),
                Arg::Host(x_t),
                Arg::Dev(&dev[&wo]),
                Arg::Dev(&dev[&w1]),
                Arg::Dev(&dev[&w2]),
                Arg::Dev(&dev[&ln2]),
            ];
            let outs = rt.call("layer_post_dense", &args)?;
            return Ok(outs.into_iter().next().unwrap());
        }

        // Sparse: widest head-row in tokens -> staging variant.
        let per_head =
            active.iter().any(|&i| sel_bufs[i].kind() == SelKind::PerHead);
        let t_cap = variant.expect("checked above");
        let heads = if per_head { h_all } else { hkv };
        let set = arena.sparse(b, heads, t_cap, dh);
        let geom = SparseGeom { heads, group: g, per_head, block_size: bs,
                                t_cap, dh };
        if let Some(t) = offload.as_mut() {
            for &i in active {
                let kvl = &slots[i].as_ref().unwrap().kv[l];
                let buf = &sel_bufs[i];
                for hr in 0..heads {
                    for &j in gather::selected_row(buf, hr, per_head, g) {
                        t.touch(kvl.pages[j as usize]);
                    }
                }
            }
        }
        {
            let (k_sel, v_sel, mask, dirty) = set.parts_mut();
            gather::gather_sparse_into(pool, active.len(), &job_at, &geom,
                                       k_sel, v_sel, mask, dirty, par);
        }
        let mut dense_equiv = 0u64;
        let mut touched_total = 0u64;
        for &i in active {
            let ctx = slots[i].as_ref().unwrap().kv[l].len;
            let staged: usize = set.dirty()[i * heads..(i + 1) * heads].iter().sum();
            let touched = 2 * (staged * dh * 4) as u64;
            dense_equiv += 2 * (ctx * dh * 4) as u64 * hkv as u64;
            touched_total += touched;
            slots[i].as_mut().unwrap().stats.kv_bytes_touched += touched;
        }
        metrics.kv_bytes_touched += touched_total;
        metrics.kv_bytes_dense_equiv += dense_equiv;
        let exe = if per_head {
            format!("layer_post_selh_t{t_cap}")
        } else {
            format!("layer_post_sel_t{t_cap}")
        };
        let args = [
            Arg::Host(q_rope_t),
            Arg::Host(&set.k),
            Arg::Host(&set.v),
            Arg::Host(&set.mask),
            Arg::Host(x_t),
            Arg::Dev(&dev[&wo]),
            Arg::Dev(&dev[&w1]),
            Arg::Dev(&dev[&w2]),
            Arg::Dev(&dev[&ln2]),
        ];
        let outs = rt.call(&exe, &args)?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Drop a departing slot's prefix pins and release its KV pages.
    /// Every terminal and preemption path funnels here, so a
    /// half-prefilled slot killed by cancellation, deadline, fault, or
    /// preemption can never leak a pin or a page reference. Offload
    /// fast-tier entries are invalidated only when this release actually
    /// frees the page — a prefix-cache reference keeps a shared page
    /// resident (and its fast-tier residency useful) past any one
    /// sequence.
    fn release_slot_resources(&mut self, slot: &mut Slot) {
        if slot.prefix_blocks > 0 {
            if let Some(pc) = self.prefix.as_mut() {
                pc.unpin(slot.prefix_hash, slot.prefix_blocks);
            }
            slot.prefix_blocks = 0;
            slot.prefix_hash = ROOT_HASH;
        }
        for kv in &mut slot.kv {
            if let Some(t) = &mut self.offload {
                for &pg in &kv.pages {
                    if self.pool.ref_count(pg) == 1 {
                        t.invalidate(pg);
                    }
                }
            }
            kv.release(&mut self.pool);
        }
    }

    fn check_stop(&mut self, i: usize, tok: i32) {
        let max_seq = self.max_seq;
        let eos = self.vocab.eos;
        let slot = self.slots[i].as_mut().unwrap();
        if let Some(stop) = stop_for(slot, tok, eos, max_seq) {
            slot.stop = Some(stop);
        }
    }

    /// Emit finished slots as `Finished` events, releasing their pages
    /// (off-slot early completions first).
    fn reap_into(&mut self, sink: &mut dyn FnMut(EngineEvent)) {
        for c in self.done_early.drain(..) {
            self.metrics.record_completion(c.ttft, c.e2e, c.generated.len(),
                                           c.stop);
            sink(EngineEvent::Finished(c));
        }
        for i in 0..self.batch {
            let finished = self.slots[i]
                .as_ref()
                .map(|s| s.stop.is_some())
                .unwrap_or(false);
            if finished {
                let mut slot = self.slots[i].take().unwrap();
                if slot.prefilling() {
                    // Cancelled / expired / exhausted half-prefilled: drop
                    // the staging resume cursor so the next prefill
                    // acquire reclaims the row; pages free below through
                    // the exact same path a decoded slot uses.
                    self.arena.abort_prefill_row(i);
                }
                self.release_slot_resources(&mut slot);
                let now = Instant::now();
                let ttft = slot
                    .first_token
                    .map(|t| t - slot.admitted)
                    .unwrap_or_default();
                let e2e = now - slot.admitted;
                let stop = slot.stop.unwrap();
                self.metrics.record_completion(ttft, e2e, slot.generated.len(),
                                               stop);
                sink(EngineEvent::Finished(Completion {
                    id: slot.req.id,
                    prompt_len: slot.req.prompt.len(),
                    generated: slot.generated,
                    stop,
                    ttft,
                    e2e,
                    stats: slot.stats,
                }));
            }
        }
    }
}

/// The serving-layer contract ([`EngineGroup`] shards, `TraceRunner`,
/// the TCP server) delegated to the inherent methods. The engine stays
/// `!Send` (it holds `Rc<Runtime>`), so a shard factory must construct
/// it on the shard thread — see `coordinator::shard`.
///
/// [`EngineGroup`]: super::shard::EngineGroup
impl DecodeEngine for Engine {
    fn submit_at(&mut self, req: Request, arrived: Instant) {
        Engine::submit_at(self, req, arrived);
    }

    fn submit_queued(&mut self, q: QueuedReq) {
        Engine::submit_queued(self, q);
    }

    fn page_geometry(&self) -> PageGeometry {
        PageGeometry {
            pool_pages: self.pool.capacity(),
            tokens_per_page: self.ecfg.block_size,
            rows_per_seq: self.cfg.n_layers,
            fixed_pages_per_seq: 0,
            slots: self.batch,
        }
    }

    fn min_priority(&self) -> Option<Priority> {
        self.slots
            .iter()
            .flatten()
            .filter(|s| s.stop.is_none())
            .map(|s| s.req.priority)
            .chain(self.queue.iter().map(|q| q.req.priority))
            .min()
    }

    fn step(&mut self) -> Result<Vec<Completion>> {
        Engine::step(self)
    }

    fn step_events(&mut self, sink: &mut dyn FnMut(EngineEvent)) -> Result<()> {
        Engine::step_core(self, sink)
    }

    fn cancel(&mut self, id: u64) -> bool {
        Engine::cancel(self, id)
    }

    fn idle(&self) -> bool {
        Engine::idle(self)
    }

    fn pending(&self) -> usize {
        Engine::pending(self)
    }

    fn active(&self) -> usize {
        Engine::active(self)
    }

    fn batch_size(&self) -> usize {
        Engine::batch_size(self)
    }

    fn max_prompt_len(&self) -> usize {
        // submit asserts prompt.len() + 2 < max_seq.
        self.max_seq.saturating_sub(3)
    }

    fn take_metrics(&mut self) -> Metrics {
        std::mem::take(&mut self.metrics)
    }
}
